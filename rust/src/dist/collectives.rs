//! Deterministic collectives over [`Mat`] buffers, in two
//! interchangeable algorithms ([`Algo`]).
//!
//! Every reducing collective combines rank contributions with one fixed
//! balanced halving tree ([`tree_sum_f64`] / the private `tree_combine`),
//! so the floating-point reduction order is a function of the world size
//! alone — never of thread scheduling, transport, *or algorithm*. This
//! extends the crate's serial/pooled bitwise-parity contract
//! (`rust/tests/parallel.rs`) to the distributed layer; the star/ring ×
//! local/socket conformance suite in `rust/tests/dist.rs` asserts it on
//! randomized shapes.
//!
//! # The two algorithms
//!
//! - [`Algo::Star`] routes every collective through the
//!   barrier-exchange primitive ([`Communicator::exchange_mats`]): each
//!   rank deposits its payload, receives all `R` payloads, and reduces
//!   locally. On the socket transport this is a rank-0 fan-in — rank 0
//!   moves `O(R²·N)` bytes per all-reduce, the bottleneck at larger
//!   worlds.
//! - [`Algo::Ring`] (the default, [`super::default_algo`]) is built on
//!   the point-to-point seam ([`Communicator::send_recv_bytes`]):
//!   a **pairwise-exchange reduce-scatter** followed by a **ring
//!   all-gather**. The payload is chunked by the canonical shard plan
//!   ([`super::shard::row_shard_range`], so the chunk schedule is a pure
//!   function of `(len, world)`); at step `s ∈ 1..R` rank `r` sends its
//!   contribution for chunk `(r+s) mod R` to that chunk's owner and
//!   receives rank `(r−s) mod R`'s contribution for its own chunk. After
//!   `R−1` steps the owner holds all `R` raw contributions and reduces
//!   them **with the same halving tree the star uses** — in-transit
//!   accumulation would force a sequential fold and break star/ring
//!   bitwise parity, so the reduction happens at the destination. The
//!   reduced chunks then circulate around the ring (`R−1` neighbor hops,
//!   pure data movement). Every rank sends `2·(R−1)/R·N` bytes per
//!   all-reduce — balanced, no hotspot (`rust/src/dist/traffic.rs`
//!   measures exactly this in `benches/dist_scaling.rs`).
//!
//! # The chunk-pipelined ring
//!
//! With overlap enabled ([`Communicator::overlap`], the default) the
//! ring all-reduce runs **chunk-pipelined**
//! ([`all_reduce_sum_pipelined`]): the flattened payload is split into
//! pipeline stages by the same canonical plan
//! ([`super::shard::row_shard_range`] at the stage level, then per rank
//! within each stage), every stage's reduce-scatter rounds are issued as
//! nonblocking ops ([`Communicator::istart_send_recv_bytes`]) a fixed
//! depth ahead, and the issuing thread reduces and all-gathers stage `m`
//! while the progress engine moves stage `m+1`'s bytes — the
//! destination tree reduction and the encode/decode work hide behind
//! the wire, and in steady state both directions of every link stay
//! busy. The schedule (stage count, issue order, chunk ranges) is a pure
//! function of `(len, world)` and identical on every rank, and each
//! element is still reduced at its destination with the same rank-
//! indexed halving tree, so the pipelined ring is **bitwise identical**
//! to the blocking ring and the star on any input — asserted across
//! transports, world sizes and stage counts in `rust/tests/dist.rs`.
//!
//! # Rank-count invariance
//!
//! A fixed-order reduction makes results reproducible *at a fixed world
//! size*. Bitwise invariance *across* world sizes additionally needs the
//! leaf partition to align with the tree: a sum over `m` items sharded
//! contiguously across `R = 2^k` ranks (with `R | m`) reproduces the
//! single-rank halving tree exactly, because each rank's local subtree is
//! a complete subtree of the global one and the cross-rank combine is the
//! tree's top `k` levels. The training driver relies on this for loss
//! accumulation, and sidesteps the question entirely for gradients by
//! gathering raw statistics rows (exact concatenation) and all-reducing
//! zero-padded updates (one nonzero contributor per element — any
//! reduction order gives the same bits).
//!
//! # Wire dtype
//!
//! Bulk collectives honor [`Communicator::wire_dtype`]: contributions
//! are *snapped* to the wire format's representable set
//! ([`crate::numerics::Dtype::round`]) before any byte leaves a rank,
//! p2p chunk payloads and encoded gather lists carry dtype-width element
//! images (2 bytes under `bf16`/`fp16`), and every reduced chunk is
//! re-snapped before it circulates — so the values on the wire are
//! always exactly representable and the narrowing encode is lossless.
//! The reduction contract becomes `snap(tree(snap(contributions)))`,
//! identical across star/ring × transports × overlap at a fixed wire
//! dtype (the refined contract 7). [`Dtype::F32`] snaps are identity and
//! the byte images are the classic 4-byte frames, so the default path is
//! untouched bit for bit. [`broadcast`] stays exact on any wire dtype —
//! it replicates checkpoint/init state, not per-step gradients, and
//! forwards the root's bytes unmodified either way.

use super::transport::{decode_mats, decode_mats_wire, encode_mats, encode_mats_wire};
use super::{Communicator, PendingOp};
use crate::numerics::{Bf16, Dtype, Fp16};
use crate::tensor::Mat;
use std::collections::VecDeque;
use std::sync::Arc;

/// Collective algorithm selector: rank-0 fan-in star vs bandwidth-optimal
/// ring (see the module docs for schedules and byte counts). Both are
/// bitwise identical on any input; the knob is purely about where the
/// bytes flow.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Gather every payload at every rank through the rank-0 barrier
    /// exchange and reduce locally.
    Star,
    /// Pairwise-exchange reduce-scatter + ring all-gather over the
    /// point-to-point seam; `~2·(R−1)/R·N` bytes per rank.
    Ring,
}

impl Algo {
    /// Parse `"star"` / `"ring"` (aliases: `"fanin"`, `"tree"` for star;
    /// `"pairwise"` for ring).
    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "star" | "fanin" | "fan-in" | "tree" => Some(Algo::Star),
            "ring" | "pairwise" => Some(Algo::Ring),
            _ => None,
        }
    }

    /// Canonical name (the string [`Algo::parse`] round-trips).
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Star => "star",
            Algo::Ring => "ring",
        }
    }
}

/// Balanced halving-tree sum: `tree(x) = tree(x[..⌈n/2⌉]) + tree(x[⌈n/2⌉..])`.
///
/// The reduction tree is a function of `n` alone. For `n` divisible by a
/// power of two `R`, the first `log2(R)` split points land on multiples
/// of `n/R`, so contiguous equal shards are complete subtrees — the
/// alignment property the rank-invariance contract builds on.
pub fn tree_sum_f64(xs: &[f64]) -> f64 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        n => {
            let mid = n.div_ceil(2);
            tree_sum_f64(&xs[..mid]) + tree_sum_f64(&xs[mid..])
        }
    }
}

/// Elementwise halving-tree sum of per-rank matrix lists.
fn tree_combine(parts: &[Arc<Vec<Mat>>]) -> Vec<Mat> {
    match parts.len() {
        0 => Vec::new(),
        1 => parts[0].as_ref().clone(),
        n => {
            let mid = n.div_ceil(2);
            let mut acc = tree_combine(&parts[..mid]);
            let hi = tree_combine(&parts[mid..]);
            assert_eq!(acc.len(), hi.len(), "all_reduce: payload length mismatch");
            for (a, b) in acc.iter_mut().zip(&hi) {
                a.axpy(1.0, b);
            }
            acc
        }
    }
}

/// Elementwise halving-tree sum of per-rank f32 chunks — the same
/// association order as `tree_combine` (`x + 1.0·y` and `x + y` are the
/// same operation bit for bit), so the ring's destination reduction is
/// bitwise identical to the star path. Consumes the contributions (the
/// callers build them for this call alone), so leaves move instead of
/// copying. The `split_off` point equals the slice split of
/// `tree_combine`, so the association order is identical.
fn tree_combine_f32(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
    match parts.len() {
        0 => Vec::new(),
        1 => parts.pop().unwrap(),
        n => {
            let hi_parts = parts.split_off(n.div_ceil(2));
            let mut acc = tree_combine_f32(parts);
            let hi = tree_combine_f32(hi_parts);
            assert_eq!(acc.len(), hi.len(), "ring reduce: chunk length mismatch");
            for (a, b) in acc.iter_mut().zip(&hi) {
                *a += *b;
            }
            acc
        }
    }
}

/// The pairwise-exchange reduce-scatter phase shared by every ring
/// reducing collective: `range_of(c)` is chunk `c`'s contiguous element
/// range of `flat`; at step `s ∈ 1..R` this rank sends its elements for
/// chunk `(rank+s) mod R` to that chunk's owner and receives rank
/// `(rank−s) mod R`'s contribution for its own chunk, then reduces all
/// `R` raw contributions with the canonical halving tree (no in-transit
/// accumulation — the destination owns the reduction order). Returns
/// this rank's reduced chunk.
fn ring_reduce_phase(
    comm: &dyn Communicator,
    flat: &[f32],
    range_of: impl Fn(usize) -> std::ops::Range<usize>,
) -> Vec<f32> {
    let world = comm.world_size();
    let rank = comm.rank();
    let wire = comm.wire_dtype();
    let my = range_of(rank);
    let mut contrib: Vec<Vec<f32>> = vec![Vec::new(); world];
    contrib[rank] = flat[my.clone()].to_vec();
    for s in 1..world {
        let to = (rank + s) % world;
        let from = (rank + world - s) % world;
        let got = comm.send_recv_bytes(to, &chunk_to_bytes(wire, &flat[range_of(to)]), from);
        contrib[from] = bytes_to_chunk(wire, &got, my.len());
    }
    tree_combine_f32(contrib)
}

/// Snap every element to the wire format's representable set (identity
/// at [`Dtype::F32`]). Idempotent, so a pre-snapped buffer is unchanged
/// bit for bit — the property that makes the dtype-width chunk encode
/// lossless everywhere it is applied.
fn snap_slice(wire: Dtype, xs: &mut [f32]) {
    if wire != Dtype::F32 {
        for v in xs.iter_mut() {
            *v = wire.round(*v);
        }
    }
}

/// Snapped copy of a matrix list (no copy avoidance at `F32` — callers
/// on that path skip the call entirely).
fn snap_mats(wire: Dtype, mats: &[Mat]) -> Vec<Mat> {
    mats.iter()
        .map(|m| {
            let mut data = m.data().to_vec();
            snap_slice(wire, &mut data);
            Mat::from_vec(m.rows(), m.cols(), data)
        })
        .collect()
}

/// Wire-dtype LE-byte image of a chunk (the p2p payload format;
/// `PROTOCOL.md` §Ring chunks): 4-byte f32 bits at [`Dtype::F32`],
/// 2-byte half bits otherwise. Callers snap first, so the narrowing is
/// bit-exact either way.
fn chunk_to_bytes(wire: Dtype, xs: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(wire.bytes() * xs.len());
    match wire {
        Dtype::F32 => {
            for v in xs {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        Dtype::Bf16 => {
            for v in xs {
                buf.extend_from_slice(&Bf16::from_f32(*v).bits().to_le_bytes());
            }
        }
        Dtype::Fp16 => {
            for v in xs {
                buf.extend_from_slice(&Fp16::from_f32(*v).bits().to_le_bytes());
            }
        }
    }
    buf
}

/// Decode a chunk, checking the element count the schedule prescribes —
/// a mismatch is an SPMD call-order violation, not data to interpret.
fn bytes_to_chunk(wire: Dtype, bytes: &[u8], expect: usize) -> Vec<f32> {
    assert_eq!(
        bytes.len(),
        wire.bytes() * expect,
        "dist: ring chunk size mismatch (SPMD call order violated?)"
    );
    match wire {
        Dtype::F32 => {
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
        }
        Dtype::Bf16 => bytes
            .chunks_exact(2)
            .map(|c| Bf16::from_bits(u16::from_le_bytes(c.try_into().unwrap())).to_f32())
            .collect(),
        Dtype::Fp16 => bytes
            .chunks_exact(2)
            .map(|c| Fp16::from_bits(u16::from_le_bytes(c.try_into().unwrap())).to_f32())
            .collect(),
    }
}

/// All-reduce (sum) a list of matrices: every rank contributes its list,
/// every rank receives the elementwise halving-tree sum of the
/// wire-snapped contributions, re-snapped
/// (`snap(tree(snap(contributions)))`; snap is identity on the default
/// `F32` wire). Shapes must agree across ranks. Dispatches on
/// [`Communicator::algo`] — and, under [`Algo::Ring`], on
/// [`Communicator::overlap`]: the chunk-pipelined schedule
/// ([`all_reduce_sum_pipelined`]) when overlap is enabled, the blocking
/// ring otherwise. All paths produce identical bits at a fixed wire
/// dtype.
pub fn all_reduce_sum(comm: &dyn Communicator, mats: &[Mat]) -> Vec<Mat> {
    if comm.world_size() == 1 {
        return mats.to_vec();
    }
    match comm.algo() {
        Algo::Star => {
            let wire = comm.wire_dtype();
            let contribution =
                if wire == Dtype::F32 { mats.to_vec() } else { snap_mats(wire, mats) };
            let parts = comm.exchange_mats_wire(contribution);
            let mut out = tree_combine(&parts);
            for m in &mut out {
                snap_slice(wire, m.data_mut());
            }
            out
        }
        Algo::Ring => {
            if comm.overlap() {
                all_reduce_sum_pipelined(comm, mats)
            } else {
                ring_all_reduce(comm, mats)
            }
        }
    }
}

/// Number of pipeline stages the auto-chunked pipelined ring uses for a
/// `total_elems` payload: one stage per [`PIPELINE_CHUNK_ELEMS`] elements,
/// clamped to `1..=`[`MAX_PIPELINE_STAGES`]. A pure function of the
/// payload size (and trivially 1 at world 1), so the stage plan is SPMD-
/// identical on every rank.
pub fn pipeline_stages(total_elems: usize, world: usize) -> usize {
    if world <= 1 {
        return 1;
    }
    (total_elems / PIPELINE_CHUNK_ELEMS).clamp(1, MAX_PIPELINE_STAGES)
}

/// Elements per pipeline stage the auto plan targets (128 KiB of f32s —
/// big enough that per-stage frame headers are noise, small enough that
/// several stages fit in flight for the payloads the training driver
/// reduces).
pub const PIPELINE_CHUNK_ELEMS: usize = 1 << 15;

/// Upper bound on auto-chunked pipeline stages (beyond a handful of
/// stages in flight the overlap is already saturated; more stages only
/// add header and scheduling overhead).
pub const MAX_PIPELINE_STAGES: usize = 8;

/// How many stages ahead the pipelined ring issues reduce-scatter
/// rounds: enough that the engine always has wire work queued while this
/// thread reduces, without buffering the whole payload twice.
const PIPELINE_DEPTH: usize = 2;

/// Chunk-pipelined ring all-reduce with the auto stage plan
/// ([`pipeline_stages`]); see [`all_reduce_sum_pipelined_stages`].
pub fn all_reduce_sum_pipelined(comm: &dyn Communicator, mats: &[Mat]) -> Vec<Mat> {
    let total: usize = mats.iter().map(|m| m.len()).sum();
    all_reduce_sum_pipelined_stages(comm, mats, pipeline_stages(total, comm.world_size()))
}

/// Chunk-pipelined ring all-reduce with an explicit stage count
/// (clamped to at least 1): the overlapped schedule described in the
/// module docs, bitwise identical to [`all_reduce_sum`] under either
/// algorithm on any input and any stage count — the conformance suite
/// in `rust/tests/dist.rs` sweeps `stages ∈ {1, 2, 3}` against the
/// blocking ring and the star across transports.
pub fn all_reduce_sum_pipelined_stages(
    comm: &dyn Communicator,
    mats: &[Mat],
    stages: usize,
) -> Vec<Mat> {
    if comm.world_size() == 1 {
        return mats.to_vec();
    }
    let mut flat = flatten(mats);
    snap_slice(comm.wire_dtype(), &mut flat);
    let reduced = ring_all_reduce_flat_pipelined(comm, &flat, stages);
    unflatten(mats, &reduced)
}

/// Broadcast `root`'s matrices to every rank. Non-root contributions are
/// ignored (ranks other than `root` may pass an empty list). Under
/// [`Algo::Ring`] the payload is store-and-forwarded around the ring
/// from the root — each rank fully receives, then forwards the identical
/// bytes once, so the farthest rank waits `R−1` sequential hops. That
/// trades latency for the star's rank-0 byte hotspot; broadcast is not
/// on the training path (chunk the forward into a true pipeline before
/// reaching for it with large payloads there).
pub fn broadcast(comm: &dyn Communicator, root: usize, mats: Vec<Mat>) -> Vec<Mat> {
    assert!(root < comm.world_size(), "broadcast: bad root");
    if comm.world_size() == 1 {
        return mats;
    }
    match comm.algo() {
        Algo::Star => {
            let payload = if comm.rank() == root { mats } else { Vec::new() };
            let parts = comm.exchange_mats(payload);
            parts[root].as_ref().clone()
        }
        Algo::Ring => ring_broadcast(comm, root, mats),
    }
}

/// All-gather arbitrary per-rank matrix lists, returned in rank order.
/// Pure data movement after the one-time wire snap: contributions are
/// quantized to [`Communicator::wire_dtype`] at the source (identity on
/// the default `F32` wire — then the gather is exact) and every rank
/// receives identical bits on any algorithm/transport. Under
/// [`Algo::Ring`] the encoded lists circulate over neighbor links
/// (`R−1` hops, forwarded byte-identically), replacing the star's rank-0
/// fan-in; this is the collective behind the training driver's
/// statistics gather.
pub fn all_gather(comm: &dyn Communicator, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
    if comm.world_size() == 1 {
        return vec![Arc::new(mats)];
    }
    let wire = comm.wire_dtype();
    let mats = if wire == Dtype::F32 {
        mats
    } else {
        let mut mats = mats;
        for m in &mut mats {
            snap_slice(wire, m.data_mut());
        }
        mats
    };
    match comm.algo() {
        Algo::Star => comm.exchange_mats_wire(mats),
        // A gather is pure data movement: a zero-copy transport returns
        // the identical bits without the ring's encode/forward/decode
        // hops (see [`Communicator::gather_zero_copy`]); wire transports
        // fall through to the real ring.
        Algo::Ring => match comm.gather_zero_copy(mats) {
            Ok(parts) => parts,
            Err(mats) => ring_all_gather_lists(comm, mats),
        },
    }
}

/// All-gather by row concatenation: every rank contributes a
/// `rows_r × cols` block; every rank receives the `Σ rows_r × cols`
/// vertical stack in rank order. Pure data movement — no floating-point
/// reduction — so the result is exact for any world size.
pub fn all_gather_rows(comm: &dyn Communicator, m: &Mat) -> Mat {
    if comm.world_size() == 1 {
        return m.clone();
    }
    let parts = all_gather(comm, vec![m.clone()]);
    concat_rows(&parts, 0)
}

/// Stack `parts[r][idx]` over ranks `r` (shared by `all_gather_rows` and
/// the multi-matrix gathers in the training driver).
pub fn concat_rows(parts: &[Arc<Vec<Mat>>], idx: usize) -> Mat {
    let cols = parts[0][idx].cols();
    let rows: usize = parts.iter().map(|p| p[idx].rows()).sum();
    let mut out = Mat::zeros(rows, cols);
    let mut r0 = 0usize;
    for p in parts {
        let blk = &p[idx];
        assert_eq!(blk.cols(), cols, "concat_rows: column mismatch");
        out.data_mut()[r0 * cols..(r0 + blk.rows()) * cols].copy_from_slice(blk.data());
        r0 += blk.rows();
    }
    out
}

/// Reduce-scatter over rows: halving-tree-sum every rank's `rows × cols`
/// contribution, then hand rank `r` its contiguous row block under the
/// canonical shard plan of [`super::shard::row_shard_range`]. World
/// sizes that do not divide the row count follow that padding rule
/// (shard heights differ by at most one; a block is empty only when
/// `rows < world`); when `world` divides `rows` every rank receives
/// exactly `rows/world` rows. Under [`Algo::Ring`] this is the
/// pairwise-exchange phase alone (`(R−1)/R·N` bytes per rank) — the row
/// blocks are already at their owners, so no all-gather follows.
pub fn reduce_scatter_rows(comm: &dyn Communicator, m: &Mat) -> Mat {
    let world = comm.world_size();
    if world == 1 {
        return m.clone();
    }
    match comm.algo() {
        Algo::Star => {
            let summed = all_reduce_sum(comm, std::slice::from_ref(m));
            let total = &summed[0];
            let block = super::shard::row_shard_range(total.rows(), world, comm.rank());
            Mat::from_fn(block.len(), total.cols(), |r, c| total.at(block.start + r, c))
        }
        Algo::Ring => ring_reduce_scatter_rows(comm, m),
    }
}

// ---------------------------------------------------------------------
// Ring implementations (over the point-to-point seam).

/// Concatenate a matrix list's elements into one flat buffer (the ring
/// all-reduce element space).
fn flatten(mats: &[Mat]) -> Vec<f32> {
    let mut flat: Vec<f32> = Vec::with_capacity(mats.iter().map(|m| m.len()).sum());
    for m in mats {
        flat.extend_from_slice(m.data());
    }
    flat
}

/// Rebuild a matrix list with `mats`' shapes from a flat element buffer.
fn unflatten(mats: &[Mat], flat: &[f32]) -> Vec<Mat> {
    let mut out = Vec::with_capacity(mats.len());
    let mut off = 0usize;
    for m in mats {
        let n = m.len();
        out.push(Mat::from_vec(m.rows(), m.cols(), flat[off..off + n].to_vec()));
        off += n;
    }
    out
}

/// Ring all-reduce of a matrix list: flatten, snap to the wire dtype,
/// pairwise-exchange reduce-scatter over the element space, halving-tree
/// reduce each chunk at its destination, ring all-gather, unflatten.
fn ring_all_reduce(comm: &dyn Communicator, mats: &[Mat]) -> Vec<Mat> {
    let mut flat = flatten(mats);
    snap_slice(comm.wire_dtype(), &mut flat);
    let reduced = ring_all_reduce_flat(comm, &flat);
    unflatten(mats, &reduced)
}

/// The chunk-pipelined flat ring all-reduce behind
/// [`all_reduce_sum_pipelined_stages`]. Stage `m` covers element range
/// `row_shard_range(len, stages, m)`; within a stage, rank `c`'s chunk
/// is `row_shard_range(stage_len, world, c)` offset into the stage — so
/// for `stages = 1` the chunk plan is exactly the blocking ring's.
/// Reduce-scatter rounds carry data straight from the *input* buffer, so
/// they are issued [`PIPELINE_DEPTH`] stages ahead as nonblocking ops;
/// each stage's destination tree reduction and dependent all-gather
/// chain then run while the engine moves later stages' rounds. The issue
/// order is a pure function of `(len, world, stages)` — identical on
/// every rank — so the per-link wire order equals the blocking order and
/// the result is bitwise identical (contract 4).
fn ring_all_reduce_flat_pipelined(
    comm: &dyn Communicator,
    flat: &[f32],
    stages: usize,
) -> Vec<f32> {
    let world = comm.world_size();
    let rank = comm.rank();
    let wire = comm.wire_dtype();
    let total = flat.len();
    let stages = stages.max(1);
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let stage_rg = |m: usize| super::shard::row_shard_range(total, stages, m);
    let chunk = |m: usize, c: usize| {
        let mr = stage_rg(m);
        let r = super::shard::row_shard_range(mr.len(), world, c);
        mr.start + r.start..mr.start + r.end
    };
    // Phase 1 of stage m: the pairwise-exchange rounds, payloads sliced
    // from the input — independent of every other stage, so issueable
    // ahead of time.
    let issue_phase1 = |m: usize| -> Vec<PendingOp<Vec<u8>>> {
        (1..world)
            .map(|s| {
                let to = (rank + s) % world;
                let from = (rank + world - s) % world;
                comm.istart_send_recv_bytes(to, chunk_to_bytes(wire, &flat[chunk(m, to)]), from)
            })
            .collect()
    };
    let mut out = vec![0f32; total];
    let mut in_flight: VecDeque<Vec<PendingOp<Vec<u8>>>> = VecDeque::new();
    for m in 0..PIPELINE_DEPTH.min(stages) {
        in_flight.push_back(issue_phase1(m));
    }
    for m in 0..stages {
        if m + PIPELINE_DEPTH < stages {
            in_flight.push_back(issue_phase1(m + PIPELINE_DEPTH));
        }
        let my = chunk(m, rank);
        let mut contrib: Vec<Vec<f32>> = vec![Vec::new(); world];
        contrib[rank] = flat[my.clone()].to_vec();
        let ops = in_flight.pop_front().expect("pipelined ring: missing phase-1 ops");
        for (s, op) in (1..world).zip(ops) {
            let from = (rank + world - s) % world;
            contrib[from] = bytes_to_chunk(wire, &op.wait(), my.len());
        }
        // Destination reduction: the same rank-indexed halving tree as
        // the blocking ring and the star — this compute overlaps the
        // engine's transfers for stages m+1..m+PIPELINE_DEPTH. The
        // reduced chunk is re-snapped before it circulates so phase 2
        // stays lossless on a half wire dtype (and the result matches
        // the star's `snap(tree(snap))` bit for bit).
        let mut reduced = tree_combine_f32(contrib);
        snap_slice(wire, &mut reduced);
        out[my.clone()].copy_from_slice(&reduced);
        // Phase 2 of stage m: circulate the reduced chunks. Each hop's
        // payload is the previous hop's receipt, so the chain is issued
        // hop by hop; later stages' phase-1 rounds are already queued
        // behind it, keeping the links busy between hops.
        let mut cursor = reduced;
        for s in 0..world - 1 {
            let recv_idx = (rank + world - s - 1) % world;
            let got =
                comm.istart_send_recv_bytes(right, chunk_to_bytes(wire, &cursor), left).wait();
            cursor = bytes_to_chunk(wire, &got, chunk(m, recv_idx).len());
            out[chunk(m, recv_idx)].copy_from_slice(&cursor);
        }
    }
    out
}

/// The flat-element-space ring all-reduce both `ring_all_reduce` and the
/// bucketed path reduce to. Chunk `c` is
/// `row_shard_range(len, world, c)` of the flattened payload; empty
/// chunks (len < world) travel as empty frames so the schedule stays
/// symmetric.
fn ring_all_reduce_flat(comm: &dyn Communicator, flat: &[f32]) -> Vec<f32> {
    let world = comm.world_size();
    let rank = comm.rank();
    let wire = comm.wire_dtype();
    let total = flat.len();
    let chunk = |c: usize| super::shard::row_shard_range(total, world, c);
    let my = chunk(rank);

    // Phase 1 — pairwise-exchange reduce-scatter; the reduced chunk is
    // re-snapped before it circulates (see the pipelined schedule).
    let mut reduced = ring_reduce_phase(comm, flat, &chunk);
    snap_slice(wire, &mut reduced);

    // Phase 2 — ring all-gather: circulate the reduced chunks clockwise;
    // at step s this rank forwards chunk (rank − s) mod world and
    // receives chunk (rank − s − 1) mod world from its left neighbor.
    let mut out = vec![0f32; total];
    out[my.clone()].copy_from_slice(&reduced);
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let mut cursor = reduced;
    for s in 0..world - 1 {
        let recv_idx = (rank + world - s - 1) % world;
        let got = comm.send_recv_bytes(right, &chunk_to_bytes(wire, &cursor), left);
        cursor = bytes_to_chunk(wire, &got, chunk(recv_idx).len());
        out[chunk(recv_idx)].copy_from_slice(&cursor);
    }
    out
}

/// Ring reduce-scatter over rows: the pairwise-exchange phase with row
/// blocks as chunks; the destination halving-tree matches the star
/// path's `tree_combine` bit for bit.
fn ring_reduce_scatter_rows(comm: &dyn Communicator, m: &Mat) -> Mat {
    let world = comm.world_size();
    let rank = comm.rank();
    let wire = comm.wire_dtype();
    let (rows, cols) = m.shape();
    // Row blocks are contiguous element ranges of the row-major data, so
    // the shared phase applies directly with a row→element range map.
    let erange = |c: usize| {
        let r = super::shard::row_shard_range(rows, world, c);
        r.start * cols..r.end * cols
    };
    let my_rows = super::shard::row_shard_range(rows, world, rank).len();
    // Snap the contribution and the reduced block so the result matches
    // the star path's `snap(tree(snap))` on any wire dtype.
    let mut flat = m.data().to_vec();
    snap_slice(wire, &mut flat);
    let mut reduced = ring_reduce_phase(comm, &flat, erange);
    snap_slice(wire, &mut reduced);
    Mat::from_vec(my_rows, cols, reduced)
}

/// Ring all-gather of per-rank matrix lists: the wire-dtype-encoded list
/// circulates over neighbor links and is forwarded byte-identically, so
/// every rank decodes the exact bytes the originator produced (the
/// caller pre-snapped the payload, so the dtype-width encode is
/// lossless).
fn ring_all_gather_lists(comm: &dyn Communicator, mats: Vec<Mat>) -> Vec<Arc<Vec<Mat>>> {
    let world = comm.world_size();
    let rank = comm.rank();
    let wire = comm.wire_dtype();
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let mut out: Vec<Option<Arc<Vec<Mat>>>> = (0..world).map(|_| None).collect();
    let mut cursor = encode_mats_wire(&mats, wire);
    out[rank] = Some(Arc::new(mats));
    for s in 0..world - 1 {
        let recv_idx = (rank + world - s - 1) % world;
        let got = comm.send_recv_bytes(right, &cursor, left);
        let decoded = decode_mats_wire(&got, wire)
            .unwrap_or_else(|e| panic!("dist: corrupt ring all-gather payload: {e}"));
        out[recv_idx] = Some(Arc::new(decoded));
        cursor = got;
    }
    out.into_iter().map(|o| o.expect("ring all-gather slot")).collect()
}

/// Ring broadcast (store-and-forward): the root sends its encoded
/// payload to its right neighbor; each rank fully receives from its left
/// and forwards the identical bytes until the ring closes (the rank
/// whose right neighbor is the root does not forward).
fn ring_broadcast(comm: &dyn Communicator, root: usize, mats: Vec<Mat>) -> Vec<Mat> {
    let world = comm.world_size();
    let rank = comm.rank();
    let right = (rank + 1) % world;
    let left = (rank + world - 1) % world;
    let (bytes, payload) = if rank == root {
        (encode_mats(&mats), mats)
    } else {
        let got = comm.recv_bytes(left);
        let decoded = decode_mats(&got)
            .unwrap_or_else(|e| panic!("dist: corrupt ring broadcast payload: {e}"));
        (got, decoded)
    };
    if right != root {
        comm.send_bytes(right, &bytes);
    }
    payload
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{run_ranks, run_ranks_algo};
    use crate::proptest::Pcg;

    #[test]
    fn algo_parse_roundtrip() {
        for a in [Algo::Star, Algo::Ring] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("pairwise"), Some(Algo::Ring));
        assert_eq!(Algo::parse("fanin"), Some(Algo::Star));
        assert!(Algo::parse("mesh").is_none());
    }

    #[test]
    fn tree_sum_uses_fixed_halving_order() {
        let xs = [0.1f64, 0.2, 0.3, 0.4];
        let want = (0.1 + 0.2) + (0.3 + 0.4);
        assert_eq!(tree_sum_f64(&xs), want);
        let xs5 = [0.1f64, 0.2, 0.3, 0.4, 0.5];
        let want5 = ((0.1 + 0.2) + 0.3) + (0.4 + 0.5);
        assert_eq!(tree_sum_f64(&xs5), want5);
        assert_eq!(tree_sum_f64(&[]), 0.0);
        assert_eq!(tree_sum_f64(&[7.0]), 7.0);
    }

    #[test]
    fn shard_subtrees_compose_to_the_global_tree() {
        // The alignment property: contiguous 2^k-way shards of a
        // divisible length reduce to the same bits as the global tree.
        let mut rng = Pcg::new(11);
        let xs: Vec<f64> = (0..96).map(|_| rng.normal() as f64).collect();
        let full = tree_sum_f64(&xs);
        for shards in [2usize, 4, 8] {
            let q = xs.len() / shards;
            let partials: Vec<f64> =
                (0..shards).map(|s| tree_sum_f64(&xs[s * q..(s + 1) * q])).collect();
            assert_eq!(tree_sum_f64(&partials).to_bits(), full.to_bits(), "shards {shards}");
        }
    }

    #[test]
    fn all_reduce_sums_with_rank_order_tree() {
        // Both algorithms must produce the same rank-indexed halving
        // tree: (r0+r1)+(r2+r3) at world 4.
        let mut rng = Pcg::new(13);
        let world = 4;
        let inputs: Vec<Mat> = (0..world).map(|_| rng.normal_mat(5, 3, 1.0)).collect();
        let want = {
            let mut a = inputs[0].clone();
            a.axpy(1.0, &inputs[1]);
            let mut b = inputs[2].clone();
            b.axpy(1.0, &inputs[3]);
            a.axpy(1.0, &b);
            a
        };
        let inp = &inputs;
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(world, algo, |c| {
                all_reduce_sum(&c, std::slice::from_ref(&inp[c.rank()]))
            });
            for out in outs {
                assert_eq!(
                    out[0].data(),
                    want.data(),
                    "{}: tree order must be rank-indexed",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let m = Mat::from_fn(2, 2, |r, c| (r * 2 + c) as f32);
        let mr = &m;
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(3, algo, |c| {
                let payload = if c.rank() == 1 { vec![mr.clone()] } else { Vec::new() };
                broadcast(&c, 1, payload)
            });
            for out in outs {
                assert_eq!(out.len(), 1, "{}", algo.name());
                assert_eq!(out[0].data(), m.data(), "{}", algo.name());
            }
        }
    }

    #[test]
    fn all_gather_rows_stacks_in_rank_order() {
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(4, algo, |c| {
                let mine = Mat::from_fn(2, 3, |r, col| (c.rank() * 100 + r * 10 + col) as f32);
                all_gather_rows(&c, &mine)
            });
            for out in outs {
                assert_eq!(out.shape(), (8, 3));
                for rank in 0..4 {
                    for r in 0..2 {
                        for col in 0..3 {
                            assert_eq!(
                                out.at(rank * 2 + r, col),
                                (rank * 100 + r * 10 + col) as f32,
                                "{}",
                                algo.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_hands_out_summed_row_blocks() {
        let world = 4;
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(world, algo, |c| {
                let mine = Mat::from_fn(8, 2, |r, col| (c.rank() + r + col) as f32);
                reduce_scatter_rows(&c, &mine)
            });
            // Sum over ranks of (rank + r + col) = 6 + 4(r + col).
            for (rank, out) in outs.iter().enumerate() {
                assert_eq!(out.shape(), (2, 2));
                for r in 0..2 {
                    for col in 0..2 {
                        let gr = rank * 2 + r;
                        assert_eq!(
                            out.at(r, col),
                            (6 + 4 * (gr + col)) as f32,
                            "{} rank {rank}",
                            algo.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_padding_rule_for_non_dividing_world() {
        // rows = 10, world = 4 → blocks 3, 3, 2, 2 of the summed matrix
        // (the row_shard_range padding rule).
        let world = 4;
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(world, algo, |c| {
                let mine = Mat::from_fn(10, 2, |r, col| (c.rank() + r + col) as f32);
                reduce_scatter_rows(&c, &mine)
            });
            let heights = [3usize, 3, 2, 2];
            let starts = [0usize, 3, 6, 8];
            for (rank, out) in outs.iter().enumerate() {
                assert_eq!(out.shape(), (heights[rank], 2), "{} rank {rank}", algo.name());
                for r in 0..heights[rank] {
                    for col in 0..2 {
                        let gr = starts[rank] + r;
                        // Sum over ranks of (rank + r + col) = 6 + 4(r + col).
                        assert_eq!(out.at(r, col), (6 + 4 * (gr + col)) as f32, "rank {rank}");
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_single_row_goes_to_rank0() {
        // 1×1 input, world 4: rank 0 receives the summed row, the rest
        // receive empty 0×1 blocks — the zero-row shard edge the ring
        // exercises per chunk.
        for algo in [Algo::Star, Algo::Ring] {
            let outs = run_ranks_algo(4, algo, |c| {
                let mine = Mat::from_vec(1, 1, vec![(c.rank() + 1) as f32]);
                reduce_scatter_rows(&c, &mine)
            });
            assert_eq!(outs[0].shape(), (1, 1), "{}", algo.name());
            assert_eq!(outs[0].at(0, 0), 10.0, "{}", algo.name());
            for out in &outs[1..] {
                assert_eq!(out.shape(), (0, 1), "{}", algo.name());
            }
        }
    }

    #[test]
    fn ring_handles_payloads_smaller_than_world() {
        // 3 elements across 4 ranks: chunk 3 is empty; empty frames keep
        // the schedule symmetric and the result exact.
        let outs = run_ranks_algo(4, Algo::Ring, |c| {
            let mine = Mat::from_vec(1, 3, vec![1.0, 2.0, c.rank() as f32]);
            all_reduce_sum(&c, std::slice::from_ref(&mine))
        });
        let want: [f32; 3] = [4.0, 8.0, (0.0 + 1.0) + (2.0 + 3.0)];
        for out in &outs {
            assert_eq!(out[0].data(), want.as_slice());
        }
    }

    #[test]
    fn pipelined_ring_matches_blocking_ring_bitwise() {
        // Stage counts from degenerate (1 = the blocking chunk plan) to
        // more stages than elements; payloads from empty to multi-stage.
        let mut rng = Pcg::new(0x9157);
        for world in [2usize, 3, 4] {
            for total in [0usize, 1, 3, 17, 12 * world] {
                let inputs: Vec<Mat> =
                    (0..world).map(|_| rng.normal_mat(1, total.max(1), 1.0)).collect();
                let inputs: Vec<Mat> = if total == 0 {
                    (0..world).map(|_| Mat::zeros(0, 4)).collect()
                } else {
                    inputs
                };
                let inp = &inputs;
                let blocking = crate::dist::run_ranks_with(world, Algo::Ring, false, |c| {
                    all_reduce_sum(&c, std::slice::from_ref(&inp[c.rank()]))
                });
                for stages in [1usize, 2, 3, 7] {
                    let pipelined = crate::dist::run_ranks_with(world, Algo::Ring, true, |c| {
                        all_reduce_sum_pipelined_stages(
                            &c,
                            std::slice::from_ref(&inp[c.rank()]),
                            stages,
                        )
                    });
                    for (b, p) in blocking.iter().zip(&pipelined) {
                        assert_eq!(
                            b[0].data(),
                            p[0].data(),
                            "world {world} total {total} stages {stages}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn overlap_dispatch_of_ring_all_reduce_is_bitwise_neutral() {
        // all_reduce_sum with overlap on (auto-pipelined) vs off
        // (blocking ring) vs star: identical bits.
        let mut rng = Pcg::new(0x0b5e);
        let world = 4;
        let inputs: Vec<Mat> = (0..world).map(|_| rng.normal_mat(9, 5, 1.0)).collect();
        let inp = &inputs;
        let star = crate::dist::run_ranks_with(world, Algo::Star, false, |c| {
            all_reduce_sum(&c, std::slice::from_ref(&inp[c.rank()]))
        });
        for overlap in [false, true] {
            let ring = crate::dist::run_ranks_with(world, Algo::Ring, overlap, |c| {
                all_reduce_sum(&c, std::slice::from_ref(&inp[c.rank()]))
            });
            for (s, r) in star.iter().zip(&ring) {
                assert_eq!(s[0].data(), r[0].data(), "overlap {overlap}");
            }
        }
    }

    #[test]
    fn pipeline_stage_plan_is_clamped_and_deterministic() {
        assert_eq!(pipeline_stages(0, 4), 1);
        assert_eq!(pipeline_stages(100, 4), 1);
        assert_eq!(pipeline_stages(PIPELINE_CHUNK_ELEMS * 3, 4), 3);
        assert_eq!(pipeline_stages(PIPELINE_CHUNK_ELEMS * 100, 4), MAX_PIPELINE_STAGES);
        assert_eq!(pipeline_stages(1 << 30, 1), 1, "world 1 needs no stages");
    }

    #[test]
    fn world1_collectives_are_identity() {
        let mut rng = Pcg::new(17);
        let m = rng.normal_mat(4, 4, 1.0);
        let mr = &m;
        let out = run_ranks(1, |c| {
            (
                all_reduce_sum(&c, std::slice::from_ref(mr)),
                all_gather_rows(&c, mr),
                broadcast(&c, 0, vec![mr.clone()]),
            )
        });
        let (ar, ag, bc) = &out[0];
        assert_eq!(ar[0].data(), m.data());
        assert_eq!(ag.data(), m.data());
        assert_eq!(bc[0].data(), m.data());
    }

    #[test]
    fn wire_chunk_codec_is_lossless_on_snapped_values() {
        let mut rng = Pcg::new(0x71fe);
        for wire in [Dtype::F32, Dtype::Bf16, Dtype::Fp16] {
            let mut xs: Vec<f32> = (0..257).map(|_| rng.normal() * 3.0).collect();
            snap_slice(wire, &mut xs);
            let bytes = chunk_to_bytes(wire, &xs);
            assert_eq!(bytes.len(), wire.bytes() * xs.len(), "{}", wire.name());
            let back = bytes_to_chunk(wire, &bytes, xs.len());
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&back), bits(&xs), "{}", wire.name());
        }
    }

    #[test]
    fn wire_half_all_reduce_is_algo_and_overlap_invariant() {
        use crate::dist::run_ranks_wire;
        // The refined contract 7: at a fixed half wire dtype, star,
        // blocking ring and pipelined ring still agree bit for bit (and
        // every element of the result is wire-representable).
        let mut rng = Pcg::new(0xa1b2);
        for world in [2usize, 3, 4] {
            let mats: Vec<Vec<Mat>> =
                (0..world).map(|_| vec![rng.normal_mat(5, 7, 1.0), rng.normal_mat(1, 3, 4.0)]).collect();
            let mref = &mats;
            for wire in [Dtype::Bf16, Dtype::Fp16] {
                let mut results: Vec<Vec<Mat>> = Vec::new();
                for (algo, overlap) in [
                    (Algo::Star, false),
                    (Algo::Ring, false),
                    (Algo::Ring, true),
                ] {
                    let out = run_ranks_wire(world, algo, overlap, wire, |c| {
                        all_reduce_sum(&c, &mref[c.rank()])
                    });
                    for r in &out {
                        for m in r {
                            for &v in m.data() {
                                assert_eq!(v.to_bits(), wire.round(v).to_bits());
                            }
                        }
                    }
                    results.push(out.into_iter().next().unwrap());
                }
                for r in &results[1..] {
                    assert_eq!(r, &results[0], "world {world} wire {}", wire.name());
                }
            }
        }
    }

    #[test]
    fn wire_half_all_gather_snaps_contributions_once() {
        use crate::dist::run_ranks_wire;
        let mut rng = Pcg::new(0xc3d4);
        let contribs: Vec<Mat> = (0..3).map(|_| rng.normal_mat(4, 5, 1.0)).collect();
        let cref = &contribs;
        for algo in [Algo::Star, Algo::Ring] {
            let out = run_ranks_wire(3, algo, false, Dtype::Bf16, |c| {
                all_gather(&c, vec![cref[c.rank()].clone()])
            });
            for parts in &out {
                for (r, p) in parts.iter().enumerate() {
                    let want: Vec<u32> =
                        cref[r].data().iter().map(|&v| Dtype::Bf16.round(v).to_bits()).collect();
                    let got: Vec<u32> = p[0].data().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want, "rank {r} {}", algo.name());
                }
            }
        }
    }
}
