//! Leveled, rank-prefixed logging.
//!
//! One process-wide level, initialized lazily from `SINGD_LOG`
//! (`error|warn|info|debug`). When `SINGD_LOG` is unset, launcher
//! processes default to [`Level::Info`] and worker processes (those
//! with `SINGD_RANK` in the environment) default to [`Level::Warn`] —
//! the logger is the single quiet-worker mechanism, replacing per-site
//! print guards. `[obs] log` config keys override via [`set_level`].
//!
//! Messages at `info`/`debug` go to stdout, `warn`/`error` to stderr,
//! matching the `println!`/`eprintln!` split of the call sites the
//! logger replaced. When the emitting thread runs inside a rank (an
//! SPMD rank body, or a worker process) the line is prefixed `[rN] `;
//! launcher output stays unprefixed so existing stdout consumers see
//! byte-identical lines.
//!
//! Use the crate-root macros, not [`emit`] directly:
//!
//! ```
//! # use singd::obs_info;
//! obs_info!("training {} ranks", 4);
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered `Error < Warn < Info < Debug` (a level enables
/// itself and everything less verbose).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or operator-facing failures (stderr).
    Error = 0,
    /// Degraded-but-continuing conditions, e.g. elastic recovery notes
    /// (stderr). The default for worker processes.
    Warn = 1,
    /// Progress output: banners, per-epoch rows, artifact paths
    /// (stdout). The default for launcher processes.
    Info = 2,
    /// Verbose diagnostics (stdout).
    Debug = 3,
}

impl Level {
    /// Parse a `SINGD_LOG` / `[obs] log` value. Case-insensitive;
    /// `None` for anything that is not one of the four level names.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// The canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

const UNINIT: u8 = 0xff;
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

fn init_level() -> Level {
    if let Some(l) = std::env::var("SINGD_LOG").ok().as_deref().and_then(Level::parse) {
        return l;
    }
    // Workers (re-exec'd with SINGD_RANK pinned) default quiet: their
    // stdout is the launcher's data channel, not a progress feed.
    if std::env::var("SINGD_RANK").is_ok() {
        Level::Warn
    } else {
        Level::Info
    }
}

/// The current process-wide level (lazily initialized, see module docs).
pub fn current() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNINIT => {
            let l = init_level();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        v => Level::from_u8(v),
    }
}

/// Override the process-wide level (config `[obs] log`, tests).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether messages at `level` are currently emitted — the cheap check
/// the macros perform before building `format_args!`.
#[inline]
pub fn enabled(level: Level) -> bool {
    level <= current()
}

/// The rank prefix for the calling thread: the SPMD thread rank when
/// set (rank bodies install it via [`crate::obs::trace::rank_scope`]),
/// else the process's `SINGD_RANK` (worker processes), else none.
fn prefix_rank() -> Option<u32> {
    let r = crate::obs::trace::thread_rank_raw();
    if r != crate::obs::trace::RANK_NONE {
        return Some(r);
    }
    static ENV_RANK: OnceLock<Option<u32>> = OnceLock::new();
    *ENV_RANK.get_or_init(|| std::env::var("SINGD_RANK").ok().and_then(|v| v.parse().ok()))
}

/// Emit one message (the macros' backend; rechecks [`enabled`]).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    match (level, prefix_rank()) {
        (Level::Error | Level::Warn, Some(r)) => eprintln!("[r{r}] {args}"),
        (Level::Error | Level::Warn, None) => eprintln!("{args}"),
        (_, Some(r)) => println!("[r{r}] {args}"),
        (_, None) => println!("{args}"),
    }
}

/// Log at [`Level::Error`] (stderr).
#[macro_export]
macro_rules! obs_error {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit($crate::obs::log::Level::Error, ::std::format_args!($($a)*));
        }
    };
}

/// Log at [`Level::Warn`] (stderr).
#[macro_export]
macro_rules! obs_warn {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit($crate::obs::log::Level::Warn, ::std::format_args!($($a)*));
        }
    };
}

/// Log at [`Level::Info`] (stdout).
#[macro_export]
macro_rules! obs_info {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit($crate::obs::log::Level::Info, ::std::format_args!($($a)*));
        }
    };
}

/// Log at [`Level::Debug`] (stdout).
#[macro_export]
macro_rules! obs_debug {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit($crate::obs::log::Level::Debug, ::std::format_args!($($a)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_four_levels_case_insensitively() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" Info "), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn levels_order_by_verbosity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_level_gates_enabled() {
        // The level is process-global; restore what other tests expect.
        let prev = current();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(prev);
    }

    #[test]
    fn names_round_trip() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()), Some(l));
        }
    }
}
