//! Per-rank structured span tracer with JSONL + Chrome `trace_event`
//! export.
//!
//! A *session* is armed per run ([`begin`]) and exported per run
//! ([`finish`]): train drivers arm one when `--trace-dir` / `[obs]
//! trace_dir` / `SINGD_TRACE` is set, benches arm an in-memory session
//! (no directory) and consume the returned events directly. While no
//! session is armed, every hook — [`span`], [`instant`], the guards —
//! is a single relaxed [`AtomicBool`] load and an immediate return:
//! the zero-overhead-when-disabled contract.
//!
//! Events carry a rank (explicit, or the calling thread's rank
//! installed by [`rank_scope`], or the session default), a small dense
//! thread id, microsecond timestamps relative to session start, and
//! typed args. [`finish`] groups events by rank and writes, per rank
//! present, `r<N>.jsonl` (one JSON object per line — the machine
//! journal) and `r<N>.trace.json` (a Chrome `trace_event` wrapper —
//! load it at `chrome://tracing` or <https://ui.perfetto.dev>).
//!
//! Timestamps never feed back into training: the non-interference
//! contract (see [`crate::obs`]) is enforced by construction — spans
//! observe, they are never consulted.

use std::cell::Cell;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Sentinel for "no rank attributed to this thread".
pub(crate) const RANK_NONE: u32 = u32::MAX;

static ACTIVE: AtomicBool = AtomicBool::new(false);

struct SessionInner {
    t0: Instant,
    dir: Option<PathBuf>,
    default_rank: u32,
    events: Mutex<Vec<Event>>,
}

fn session_slot() -> &'static Mutex<Option<Arc<SessionInner>>> {
    static S: OnceLock<Mutex<Option<Arc<SessionInner>>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(None))
}

fn cur_session() -> Option<Arc<SessionInner>> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    session_slot().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Whether a trace session is currently armed (one relaxed load — the
/// gate every hook checks first).
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm a trace session. `dir` is where [`finish`] exports the per-rank
/// artifacts (`None` = in-memory only, for benches). `default_rank`
/// attributes events from threads with no rank of their own — worker
/// processes pass their `SINGD_RANK`, single-process runs pass 0.
///
/// Returns `false` (and changes nothing) if a session is already
/// armed: nested drivers — `train_dist` delegating to
/// `train_image_model` — call [`begin`]/[`finish`] unconditionally and
/// only the outermost pair wins.
pub fn begin(dir: Option<&Path>, default_rank: usize) -> bool {
    let mut slot = session_slot().lock().unwrap_or_else(|e| e.into_inner());
    if slot.is_some() {
        return false;
    }
    *slot = Some(Arc::new(SessionInner {
        t0: Instant::now(),
        dir: dir.map(Path::to_path_buf),
        default_rank: default_rank as u32,
        events: Mutex::new(Vec::new()),
    }));
    ACTIVE.store(true, Ordering::Release);
    true
}

/// Disarm the session, export its artifacts (when it has a directory),
/// and return the recorded events sorted by `(rank, ts_us)`. A no-op
/// returning an empty `Vec` when no session is armed. Export I/O
/// failures are logged at `warn`, never raised — tracing must not be
/// able to fail a run.
pub fn finish() -> Vec<Event> {
    let inner = {
        let mut slot = session_slot().lock().unwrap_or_else(|e| e.into_inner());
        ACTIVE.store(false, Ordering::Release);
        slot.take()
    };
    let Some(inner) = inner else {
        return Vec::new();
    };
    let mut events = {
        let mut ev = inner.events.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *ev)
    };
    events.sort_by_key(|e| (e.rank, e.ts_us, e.dur_us));
    if let Some(dir) = &inner.dir {
        if let Err(e) = export(dir, &events) {
            crate::obs_warn!("obs: trace export to {} failed: {e}", dir.display());
        }
    }
    events
}

// ---------------------------------------------------------------------
// Thread attribution.
// ---------------------------------------------------------------------

thread_local! {
    static THREAD_RANK: Cell<u32> = const { Cell::new(RANK_NONE) };
}

fn thread_tid() -> u32 {
    thread_local! {
        static TID: u32 = {
            static NEXT: AtomicU32 = AtomicU32::new(1);
            NEXT.fetch_add(1, Ordering::Relaxed)
        };
    }
    TID.with(|t| *t)
}

/// The calling thread's rank, [`RANK_NONE`] when unset (used by the
/// logger's rank prefix).
pub(crate) fn thread_rank_raw() -> u32 {
    THREAD_RANK.with(|r| r.get())
}

/// Attribute the calling thread to `rank` until the guard drops
/// (restoring the previous attribution — scopes nest). Rank bodies
/// install this unconditionally: it is one thread-local store, and it
/// also rank-prefixes log lines, so it is not gated on [`active`].
pub fn rank_scope(rank: usize) -> RankScope {
    let prev = THREAD_RANK.with(|r| r.replace(rank as u32));
    RankScope { prev }
}

/// Guard restoring the previous thread-rank attribution on drop.
#[must_use = "the rank attribution ends when this guard drops"]
pub struct RankScope {
    prev: u32,
}

impl Drop for RankScope {
    fn drop(&mut self) {
        THREAD_RANK.with(|r| r.set(self.prev));
    }
}

fn resolve_rank(explicit: Option<usize>, s: &SessionInner) -> u32 {
    if let Some(r) = explicit {
        return r as u32;
    }
    let t = thread_rank_raw();
    if t != RANK_NONE {
        t
    } else {
        s.default_rank
    }
}

// ---------------------------------------------------------------------
// Events.
// ---------------------------------------------------------------------

/// A typed event argument.
#[derive(Clone, Debug)]
pub enum ArgVal {
    /// Unsigned integer (bytes, counts, ids).
    U(u64),
    /// Float (scales, fractions). Non-finite values export as `null`.
    F(f64),
    /// Short label (endpoint names, op kinds).
    S(String),
}

/// One recorded trace event: a complete span (`ph == 'X'`, with
/// duration) or an instant (`ph == 'i'`).
#[derive(Clone, Debug)]
pub struct Event {
    /// Phase or event name (`"forward_backward"`, `"op_exec"`, …).
    pub name: &'static str,
    /// Category: `"compute"`, `"comm"`, `"wait"`, `"pool"`,
    /// `"scaler"`, `"elastic"`, `"step"`.
    pub cat: &'static str,
    /// `'X'` complete span or `'i'` instant (Chrome `trace_event`
    /// phase codes).
    pub ph: char,
    /// Rank the event is attributed to (Chrome `pid`).
    pub rank: u32,
    /// Dense per-thread id (Chrome `tid`).
    pub tid: u32,
    /// Start time, µs since session start.
    pub ts_us: u64,
    /// Duration in µs (0 for instants).
    pub dur_us: u64,
    /// Typed key/value arguments.
    pub args: Vec<(&'static str, ArgVal)>,
}

fn us_since(t0: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(t0).as_micros() as u64
}

/// Record an instant event attributed to the calling thread's rank
/// (else the session default). No-op when no session is armed.
pub fn instant(name: &'static str, cat: &'static str, args: Vec<(&'static str, ArgVal)>) {
    instant_at(name, cat, None, args);
}

/// [`instant`] with an explicit rank.
pub fn instant_rank(
    name: &'static str,
    cat: &'static str,
    rank: usize,
    args: Vec<(&'static str, ArgVal)>,
) {
    instant_at(name, cat, Some(rank), args);
}

fn instant_at(
    name: &'static str,
    cat: &'static str,
    rank: Option<usize>,
    args: Vec<(&'static str, ArgVal)>,
) {
    let Some(s) = cur_session() else { return };
    let ev = Event {
        name,
        cat,
        ph: 'i',
        rank: resolve_rank(rank, &s),
        tid: thread_tid(),
        ts_us: us_since(s.t0, Instant::now()),
        dur_us: 0,
        args,
    };
    s.events.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
}

/// Open a span attributed to the calling thread's rank (else the
/// session default); it records itself when the guard drops. When no
/// session is armed this is one relaxed load and returns an inert
/// guard.
pub fn span(name: &'static str, cat: &'static str) -> Span {
    span_at(name, cat, None)
}

/// [`span`] with an explicit rank (engine threads, worker closures).
pub fn span_rank(name: &'static str, cat: &'static str, rank: usize) -> Span {
    span_at(name, cat, Some(rank))
}

fn span_at(name: &'static str, cat: &'static str, rank: Option<usize>) -> Span {
    let Some(s) = cur_session() else { return Span(None) };
    let rank = resolve_rank(rank, &s);
    Span(Some(SpanLive { s, name, cat, rank, start: Instant::now(), args: Vec::new() }))
}

struct SpanLive {
    s: Arc<SessionInner>,
    name: &'static str,
    cat: &'static str,
    rank: u32,
    start: Instant,
    args: Vec<(&'static str, ArgVal)>,
}

/// A live span guard; drop closes and records it. Inert (all methods
/// free) when tracing is disabled.
#[must_use = "the span closes when this guard drops"]
pub struct Span(Option<SpanLive>);

impl Span {
    /// Attach an argument to the span (no-op when inert).
    pub fn arg(&mut self, key: &'static str, val: ArgVal) {
        if let Some(live) = &mut self.0 {
            live.args.push((key, val));
        }
    }

    /// Whether the span is actually recording.
    pub fn is_recording(&self) -> bool {
        self.0.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(live) = self.0.take() else { return };
        let end = Instant::now();
        let ts_us = us_since(live.s.t0, live.start);
        let ev = Event {
            name: live.name,
            cat: live.cat,
            ph: 'X',
            rank: live.rank,
            tid: thread_tid(),
            ts_us,
            dur_us: us_since(live.s.t0, end).saturating_sub(ts_us),
            args: live.args,
        };
        live.s.events.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
    }
}

// ---------------------------------------------------------------------
// Export.
// ---------------------------------------------------------------------

fn json_escape(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

fn push_args(out: &mut String, args: &[(&'static str, ArgVal)]) {
    out.push('{');
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        json_escape(out, k);
        out.push_str("\":");
        match v {
            ArgVal::U(u) => out.push_str(&u.to_string()),
            ArgVal::F(f) if f.is_finite() => out.push_str(&format!("{f:?}")),
            ArgVal::F(_) => out.push_str("null"),
            ArgVal::S(s) => {
                out.push('"');
                json_escape(out, s);
                out.push('"');
            }
        }
    }
    out.push('}');
}

fn push_event(out: &mut String, e: &Event, chrome: bool) {
    out.push_str("{\"name\":\"");
    json_escape(out, e.name);
    out.push_str("\",\"cat\":\"");
    json_escape(out, e.cat);
    out.push_str("\",\"ph\":\"");
    out.push(e.ph);
    out.push('"');
    if chrome {
        out.push_str(&format!(",\"pid\":{},\"tid\":{},\"ts\":{}", e.rank, e.tid, e.ts_us));
        if e.ph == 'X' {
            out.push_str(&format!(",\"dur\":{}", e.dur_us));
        } else {
            out.push_str(",\"s\":\"t\"");
        }
    } else {
        out.push_str(&format!(
            ",\"rank\":{},\"tid\":{},\"ts_us\":{},\"dur_us\":{}",
            e.rank, e.tid, e.ts_us, e.dur_us
        ));
    }
    out.push_str(",\"args\":");
    push_args(out, &e.args);
    out.push('}');
}

fn export(dir: &Path, events: &[Event]) -> std::io::Result<()> {
    fs::create_dir_all(dir)?;
    let mut ranks: Vec<u32> = events.iter().map(|e| e.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in ranks {
        let evs: Vec<&Event> = events.iter().filter(|e| e.rank == r).collect();
        let mut jsonl = String::new();
        for e in &evs {
            push_event(&mut jsonl, e, false);
            jsonl.push('\n');
        }
        fs::write(dir.join(format!("r{r}.jsonl")), jsonl.as_bytes())?;
        let mut chrome = String::from("{\"traceEvents\":[\n");
        for (i, e) in evs.iter().enumerate() {
            push_event(&mut chrome, e, true);
            if i + 1 < evs.len() {
                chrome.push(',');
            }
            chrome.push('\n');
        }
        chrome.push_str("]}\n");
        fs::write(dir.join(format!("r{r}.trace.json")), chrome.as_bytes())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Overlap analysis.
// ---------------------------------------------------------------------

/// Per-rank comm/compute overlap summary derived from a trace: how
/// much of the rank's communication span time was hidden under (i.e.
/// wall-clock-overlapped by) its compute spans.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RankOverlap {
    /// Rank the summary describes.
    pub rank: u32,
    /// Total µs inside `cat == "comm"` spans.
    pub comm_us: u64,
    /// µs of that comm time overlapped by `cat == "compute"` spans.
    pub hidden_us: u64,
}

impl RankOverlap {
    /// Hidden fraction in `[0, 1]` (0 when no comm was recorded).
    pub fn hidden_frac(&self) -> f64 {
        if self.comm_us == 0 {
            0.0
        } else {
            self.hidden_us as f64 / self.comm_us as f64
        }
    }
}

fn merge_intervals(mut iv: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some((_, e)) if a <= *e => *e = (*e).max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Compute the per-rank comm-hidden-under-compute summary from a
/// recorded event set (the Rust twin of `tools/check_trace.py`'s
/// overlap report; `benches/dist_scaling.rs` feeds its rows from it).
pub fn overlap_stats(events: &[Event]) -> Vec<RankOverlap> {
    let mut ranks: Vec<u32> = events.iter().map(|e| e.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    ranks
        .iter()
        .map(|&rank| {
            let compute = merge_intervals(
                events
                    .iter()
                    .filter(|e| e.rank == rank && e.ph == 'X' && e.cat == "compute")
                    .map(|e| (e.ts_us, e.ts_us + e.dur_us))
                    .collect(),
            );
            let mut comm_us = 0u64;
            let mut hidden_us = 0u64;
            for e in events.iter().filter(|e| e.rank == rank && e.ph == 'X' && e.cat == "comm") {
                let (a, b) = (e.ts_us, e.ts_us + e.dur_us);
                comm_us += b - a;
                for &(ca, cb) in &compute {
                    let lo = a.max(ca);
                    let hi = b.min(cb);
                    if lo < hi {
                        hidden_us += hi - lo;
                    }
                }
            }
            RankOverlap { rank, comm_us, hidden_us }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sessions are process-global; tests that arm one serialize here.
    fn session_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn inactive_hooks_are_inert() {
        let _g = session_lock();
        assert!(!active());
        let mut sp = span("noop", "compute");
        assert!(!sp.is_recording());
        sp.arg("k", ArgVal::U(1));
        drop(sp);
        instant("noop", "compute", vec![]);
        assert!(finish().is_empty());
    }

    #[test]
    fn begin_is_exclusive_and_finish_disarms() {
        let _g = session_lock();
        assert!(begin(None, 0));
        assert!(!begin(None, 0), "second begin must lose");
        assert!(active());
        let _ = finish();
        assert!(!active());
        assert!(begin(None, 0));
        let _ = finish();
    }

    #[test]
    fn spans_and_instants_record_with_rank_attribution() {
        let _g = session_lock();
        assert!(begin(None, 3));
        {
            let _s = span("default_rank", "compute");
        }
        {
            let _scope = rank_scope(1);
            let _s = span("thread_rank", "compute");
        }
        {
            let mut s = span_rank("explicit", "comm", 2);
            s.arg("bytes", ArgVal::U(64));
        }
        instant("marker", "elastic", vec![("gen", ArgVal::U(5))]);
        let events = finish();
        assert_eq!(events.len(), 4);
        let by_name = |n: &str| events.iter().find(|e| e.name == n).unwrap();
        assert_eq!(by_name("default_rank").rank, 3);
        assert_eq!(by_name("thread_rank").rank, 1);
        assert_eq!(by_name("explicit").rank, 2);
        assert_eq!(by_name("explicit").args.len(), 1);
        assert_eq!(by_name("marker").ph, 'i');
        assert_eq!(by_name("default_rank").ph, 'X');
    }

    #[test]
    fn rank_scope_nests_and_restores() {
        assert_eq!(thread_rank_raw(), RANK_NONE);
        {
            let _a = rank_scope(4);
            assert_eq!(thread_rank_raw(), 4);
            {
                let _b = rank_scope(7);
                assert_eq!(thread_rank_raw(), 7);
            }
            assert_eq!(thread_rank_raw(), 4);
        }
        assert_eq!(thread_rank_raw(), RANK_NONE);
    }

    #[test]
    fn export_writes_per_rank_jsonl_and_chrome_files() {
        let _g = session_lock();
        let dir = std::env::temp_dir().join(format!("singd-trace-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert!(begin(Some(&dir), 0));
        {
            let _s = span_rank("alpha", "compute", 0);
        }
        {
            let _s = span_rank("beta", "comm", 1);
        }
        instant_rank("gamma", "elastic", 1, vec![("label", ArgVal::S("a\"b".into()))]);
        let events = finish();
        assert_eq!(events.len(), 3);
        for r in [0u32, 1] {
            let jsonl = fs::read_to_string(dir.join(format!("r{r}.jsonl"))).unwrap();
            for line in jsonl.lines() {
                assert!(line.starts_with('{') && line.ends_with('}'), "bad line {line:?}");
                assert!(line.contains("\"name\":\""));
            }
            let chrome = fs::read_to_string(dir.join(format!("r{r}.trace.json"))).unwrap();
            assert!(chrome.starts_with("{\"traceEvents\":["));
            assert!(chrome.trim_end().ends_with("]}"));
        }
        let r1 = fs::read_to_string(dir.join("r1.jsonl")).unwrap();
        assert!(r1.contains("a\\\"b"), "string args must be escaped: {r1}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn overlap_stats_measures_hidden_comm() {
        let ev = |cat: &'static str, ts: u64, dur: u64| Event {
            name: "e",
            cat,
            ph: 'X',
            rank: 0,
            tid: 1,
            ts_us: ts,
            dur_us: dur,
            args: vec![],
        };
        // compute covers [0,100); comm spans [50,150) and [200,210).
        let events = vec![ev("compute", 0, 100), ev("comm", 50, 100), ev("comm", 200, 10)];
        let stats = overlap_stats(&events);
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].comm_us, 110);
        assert_eq!(stats[0].hidden_us, 50);
        assert!((stats[0].hidden_frac() - 50.0 / 110.0).abs() < 1e-12);
    }

    #[test]
    fn merge_intervals_coalesces_overlaps() {
        assert_eq!(merge_intervals(vec![(5, 10), (0, 6), (20, 30)]), vec![(0, 10), (20, 30)]);
        assert_eq!(merge_intervals(vec![]), Vec::<(u64, u64)>::new());
    }
}
