//! Process-wide metrics registry plus the always-on status snapshot.
//!
//! Metrics are registered by name on first use and live for the process
//! lifetime (lookup-or-leak, the same discipline as the
//! [`crate::dist::traffic`] slots): [`counter`] / [`gauge`] / [`histo`]
//! return `&'static` handles whose update paths are single relaxed
//! atomic ops — safe on hot paths and from any thread. The crate-root
//! `obs_count!` / `obs_gauge!` / `obs_histo!` macros cache the
//! registry lookup in a per-call-site static so steady-state cost is
//! the atomic op alone.
//!
//! [`status_snapshot`] reads the live telemetry atomics (current step,
//! loss, scaler scale, world generation) that the training drivers
//! maintain unconditionally; the elastic STATUS control reply ships it
//! on the wire (PROTOCOL.md §control frames).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// A monotonically increasing event/byte counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n` (relaxed).
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value (relaxed).
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge (relaxed).
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value (relaxed).
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A count/sum/max summary of observed `u64` samples (e.g. durations
/// in µs, batch sizes). Deliberately bucket-free: cheap, lock-free,
/// and enough for mean + worst-case reporting.
#[derive(Debug, Default)]
pub struct Histo {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histo {
    /// Record one sample (relaxed).
    #[inline]
    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// `(count, sum, max)` of everything observed so far (relaxed).
    pub fn get(&self) -> (u64, u64, u64) {
        (
            self.count.load(Ordering::Relaxed),
            self.sum.load(Ordering::Relaxed),
            self.max.load(Ordering::Relaxed),
        )
    }
}

enum Slot {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histo),
}

fn registry() -> &'static Mutex<BTreeMap<String, Slot>> {
    static REG: OnceLock<Mutex<BTreeMap<String, Slot>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Look up (registering on first use) the counter named `name`.
///
/// # Panics
///
/// If `name` is already registered as a different metric kind.
pub fn counter(name: &str) -> &'static Counter {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Slot::C(Box::leak(Box::new(Counter::default()))))
    {
        Slot::C(c) => c,
        _ => panic!("obs: metric {name:?} already registered with a different kind"),
    }
}

/// Look up (registering on first use) the gauge named `name`.
///
/// # Panics
///
/// If `name` is already registered as a different metric kind.
pub fn gauge(name: &str) -> &'static Gauge {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Slot::G(Box::leak(Box::new(Gauge::default()))))
    {
        Slot::G(g) => g,
        _ => panic!("obs: metric {name:?} already registered with a different kind"),
    }
}

/// Look up (registering on first use) the histogram named `name`.
///
/// # Panics
///
/// If `name` is already registered as a different metric kind.
pub fn histo(name: &str) -> &'static Histo {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Slot::H(Box::leak(Box::new(Histo::default()))))
    {
        Slot::H(h) => h,
        _ => panic!("obs: metric {name:?} already registered with a different kind"),
    }
}

/// A snapshot value for one registered metric.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    /// Counter value.
    Counter(u64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram `(count, sum, max)`.
    Histo(u64, u64, u64),
}

/// Snapshot every registered metric, sorted by name.
pub fn snapshot() -> Vec<(String, MetricValue)> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.iter()
        .map(|(k, v)| {
            let mv = match v {
                Slot::C(c) => MetricValue::Counter(c.get()),
                Slot::G(g) => MetricValue::Gauge(g.get()),
                Slot::H(h) => {
                    let (n, s, m) = h.get();
                    MetricValue::Histo(n, s, m)
                }
            };
            (k.clone(), mv)
        })
        .collect()
}

/// Add to a named counter, caching the registry lookup per call site.
#[macro_export]
macro_rules! obs_count {
    ($name:literal, $n:expr) => {{
        static SLOT: ::std::sync::OnceLock<&'static $crate::obs::metrics::Counter> =
            ::std::sync::OnceLock::new();
        SLOT.get_or_init(|| $crate::obs::metrics::counter($name)).add($n);
    }};
}

/// Set a named gauge, caching the registry lookup per call site.
#[macro_export]
macro_rules! obs_gauge {
    ($name:literal, $v:expr) => {{
        static SLOT: ::std::sync::OnceLock<&'static $crate::obs::metrics::Gauge> =
            ::std::sync::OnceLock::new();
        SLOT.get_or_init(|| $crate::obs::metrics::gauge($name)).set($v);
    }};
}

/// Observe into a named histogram, caching the registry lookup per
/// call site.
#[macro_export]
macro_rules! obs_histo {
    ($name:literal, $v:expr) => {{
        static SLOT: ::std::sync::OnceLock<&'static $crate::obs::metrics::Histo> =
            ::std::sync::OnceLock::new();
        SLOT.get_or_init(|| $crate::obs::metrics::histo($name)).observe($v);
    }};
}

// ---------------------------------------------------------------------
// Live status snapshot (the STATUS telemetry payload).
// ---------------------------------------------------------------------

static STEP: AtomicU64 = AtomicU64::new(0);
static LOSS_BITS: AtomicU64 = AtomicU64::new(0);
static SCALE_BITS: AtomicU64 = AtomicU64::new(0);
static GEN: AtomicU64 = AtomicU64::new(0);

/// Record the current global training step (relaxed; always-on).
#[inline]
pub fn set_step(step: u64) {
    STEP.store(step, Ordering::Relaxed);
}

/// Record the most recent training loss (relaxed; always-on).
#[inline]
pub fn set_loss(loss: f64) {
    LOSS_BITS.store(loss.to_bits(), Ordering::Relaxed);
}

/// Record the current GradScaler scale (relaxed; always-on).
#[inline]
pub fn set_scale(scale: f32) {
    SCALE_BITS.store(scale.to_bits() as u64, Ordering::Relaxed);
}

/// Record the current elastic world generation (relaxed; always-on).
#[inline]
pub fn set_gen(gen: u64) {
    GEN.store(gen, Ordering::Relaxed);
}

/// The live metrics payload carried by the elastic STATUS control
/// reply: all fields are raw `u64` so the struct stays `Eq` and maps
/// 1:1 onto the 40-byte wire block (PROTOCOL.md §control frames).
/// Floats travel as IEEE-754 bits; use [`StatusMetrics::loss`] /
/// [`StatusMetrics::scale`] to decode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatusMetrics {
    /// Current global training step on the replying process.
    pub step: u64,
    /// Most recent loss, as `f64` bits.
    pub loss_bits: u64,
    /// Bytes sent by the replying process ([`crate::dist::traffic`]),
    /// current traffic epoch only.
    pub bytes: u64,
    /// Current GradScaler scale, as `f32` bits (in the low 32).
    pub scale_bits: u64,
    /// Elastic world generation the replying process is training in.
    pub gen: u64,
}

impl StatusMetrics {
    /// Decode the loss field.
    pub fn loss(&self) -> f64 {
        f64::from_bits(self.loss_bits)
    }

    /// Decode the scale field.
    pub fn scale(&self) -> f32 {
        f32::from_bits(self.scale_bits as u32)
    }
}

/// Snapshot the live telemetry atomics. `bytes` is supplied by the
/// caller (the coordinator passes its process's
/// [`crate::dist::traffic::total_sent`]) so this module stays free of
/// dist dependencies.
pub fn status_snapshot(bytes: u64) -> StatusMetrics {
    StatusMetrics {
        step: STEP.load(Ordering::Relaxed),
        loss_bits: LOSS_BITS.load(Ordering::Relaxed),
        bytes,
        scale_bits: SCALE_BITS.load(Ordering::Relaxed),
        gen: GEN.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histo_register_once_and_accumulate() {
        let c = counter("test.metrics.counter");
        c.add(3);
        counter("test.metrics.counter").add(4);
        assert_eq!(c.get(), 7);

        gauge("test.metrics.gauge").set(2.5);
        assert_eq!(gauge("test.metrics.gauge").get(), 2.5);

        let h = histo("test.metrics.histo");
        h.observe(10);
        h.observe(4);
        assert_eq!(h.get(), (2, 14, 10));
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        counter("test.metrics.kind_clash");
        gauge("test.metrics.kind_clash");
    }

    #[test]
    fn snapshot_contains_registered_metrics_sorted() {
        counter("test.metrics.snap.a").add(1);
        gauge("test.metrics.snap.b").set(1.0);
        let snap = snapshot();
        let names: Vec<&str> = snap
            .iter()
            .map(|(k, _)| k.as_str())
            .filter(|k| k.starts_with("test.metrics.snap"))
            .collect();
        assert_eq!(names, vec!["test.metrics.snap.a", "test.metrics.snap.b"]);
    }

    #[test]
    fn obs_count_macro_caches_and_adds() {
        for _ in 0..3 {
            obs_count!("test.metrics.macro_counter", 2);
        }
        assert_eq!(counter("test.metrics.macro_counter").get(), 6);
    }

    #[test]
    fn status_metrics_round_trip_float_bits() {
        let m = StatusMetrics {
            step: 7,
            loss_bits: 0.125f64.to_bits(),
            bytes: 99,
            scale_bits: 65536.0f32.to_bits() as u64,
            gen: 2,
        };
        assert_eq!(m.loss(), 0.125);
        assert_eq!(m.scale(), 65536.0);
    }
}
