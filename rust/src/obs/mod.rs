//! Observability: leveled logging, a process-wide metrics registry, and
//! a per-rank structured span tracer.
//!
//! The subsystem has three layers, each usable on its own:
//!
//! - [`log`] — a leveled, rank-prefixed logger (`SINGD_LOG=error|warn|
//!   info|debug`) behind the crate-root `obs_error!` / `obs_warn!` /
//!   `obs_info!` / `obs_debug!` macros. Worker processes (those with
//!   `SINGD_RANK` in the environment) default to `warn`, which replaces
//!   the old ad-hoc "quiet worker mode" special-casing.
//! - [`metrics`] — process-wide counters / gauges / histograms behind
//!   lookup-or-leak registration (same lifetime discipline as the
//!   [`crate::dist::traffic`] slots) plus the `obs_count!` /
//!   `obs_gauge!` / `obs_histo!` macros, and the always-on status
//!   snapshot backing the elastic STATUS telemetry reply.
//! - [`trace`] — a per-run span tracer recording step phases, pending-op
//!   lifecycles, pool batches, scaler events and elastic transitions,
//!   exported per rank as a JSONL journal (`r<N>.jsonl`) and a Chrome
//!   `trace_event` file (`r<N>.trace.json`).
//!
//! # Non-interference contract
//!
//! Observability must never perturb training math. Concretely (the
//! "sixth contract" in ARCHITECTURE.md): every value that feeds a
//! reduction, a parameter update or a digest is bitwise identical with
//! tracing enabled or disabled; timestamps exist only in exported
//! artifacts and in log lines, never in reduction order or in any
//! computed quantity. When tracing is disabled every hook is a single
//! relaxed atomic load off the hot path; registry counters are plain
//! relaxed atomic adds (the [`crate::dist::traffic`] precedent) and
//! carry no ordering anyone synchronizes on.
#![deny(missing_docs)]

pub mod log;
pub mod metrics;
pub mod trace;
