//! Hand-rolled CLI (clap is unavailable offline).
//!
//! Subcommands:
//! - `train --config <file.toml> [--out curves.csv]` — run one training job.
//! - `sweep --config <file.toml> --trials N` — Table-4 random search.
//! - `gcn --method <m> [--steps N]` — the Fig. 7 GCN job.
//! - `inspect --structure <s> --dim <d>` — print a structure's pattern,
//!   `K Kᵀ`, and memory (Figs. 5/8 in text form).
//! - `bench-help` — how to regenerate every paper table/figure.

use crate::config::JobConfig;
use crate::exp;
use crate::optim::Method;
use crate::structured::{SMat, Structure};
use std::collections::BTreeMap;

/// Parsed `--key value` flags + positional args.
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        if argv.is_empty() {
            return Err("missing subcommand".into());
        }
        let cmd = argv[0].clone();
        let mut flags = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        Ok(Args { cmd, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

pub const USAGE: &str = "\
singd — Structured Inverse-Free Natural Gradient Descent (paper reproduction)

USAGE:
  singd train   --config <file.toml> [--out <curves.csv>]
                [--ranks <R>] [--strategy <replicated|factor-sharded>]
                [--transport <local|socket>] [--algo <star|ring>]
                [--overlap <0|1>] [--stream <0|1>]
                [--wire-dtype <f32|bf16|fp16>] [--accum-steps <k>]
                [--ckpt <file.ckpt>] [--ckpt-every <N>]
                [--resume <file.ckpt>] [--elastic <0|1>]
                [--trace-dir <dir>] [--log <error|warn|info|debug>]
  singd sweep   --config <file.toml> [--trials <N>] [--seed <S>]
  singd gcn     [--method <sgd|adamw|kfac|rkfac[:k]|mac|ingd|singd:diag|...>] [--steps <N>]
  singd inspect [--structure <dense|diag|block:k|tril|rankk:k|hier:k|toeplitz>] [--dim <d>]
  singd help

Distributed training: --ranks R (default: SINGD_RANKS env, else 1) runs R
deterministic data-parallel ranks; --strategy factor-sharded additionally
shards the Kronecker factors (per-rank state ~1/R). --transport local
(default; SINGD_TRANSPORT env overrides) runs the ranks as threads of
this process; --transport socket re-execs this binary as R-1 worker
processes joined over a Unix-socket rendezvous (SINGD_RANK/SINGD_WORLD/
SINGD_RENDEZVOUS env contract). --algo ring (default; SINGD_ALGO env
overrides) runs the collectives as bandwidth-balanced ring schedules
over a full peer mesh; --algo star funnels them through rank 0 — both
are bitwise identical. --overlap 1 (default; SINGD_OVERLAP env
overrides) hides collective latency behind compute: nonblocking stats
gathers, a chunk-pipelined ring all-reduce, and bucketed update
exchanges issued ahead of their waits — bitwise identical to
--overlap 0 by the overlap-invariance contract. --stream 1 (default;
SINGD_STREAM env overrides; needs --overlap 1) fuses backward with
comm: each layer's stats gather is issued from inside that layer's
backward hook, so it rides the wire while earlier layers are still
computing — bitwise identical to --stream 0 by the stream-invariance
contract. Either transport, either algo, either overlap mode, either
stream mode at ranks=R is bitwise identical to ranks=1 for
power-of-two R dividing the batch size; non-dividing R <= batch still
train deterministically via the balanced padding rule. --accum-steps k
(default 1 = off) splits every optimizer step into k contiguous
micro-batches and folds their Kronecker stats back together — bitwise
identical to the unsplit step when each micro-batch height is a power
of two. --wire-dtype bf16|fp16 (default f32; SINGD_WIRE_DTYPE env
overrides) moves the stats gathers and update all-reduces as 2-byte
payloads (~half the per-rank wire bytes); runs stay bitwise identical
across transport x algo x overlap at a fixed wire dtype but a half
wire forfeits exact serial equality. SINGD_THREADS caps the worker
pool all ranks share.

Fault tolerance: --ckpt F --ckpt-every N writes an atomic checkpoint
(tmp + fsync + rename, last good kept as F.prev) every N steps;
--resume F restores it and continues bitwise identically to an
uninterrupted run. --elastic 1 (socket transport + Unix rendezvous
only; requires --ckpt/--ckpt-every) survives worker death: survivors
re-rendezvous into a smaller world, reshard optimizer state from the
last checkpoint, and keep training deterministically.

Observability: --trace-dir D (default: SINGD_TRACE env, else off) arms
the per-rank structured tracer — each rank writes a span/event journal
D/r<N>.jsonl plus a Chrome trace D/r<N>.trace.json (open in
chrome://tracing or ui.perfetto.dev; validate with
tools/check_trace.py). Tracing never changes training math: digests are
bitwise identical with tracing on or off. --log L (default: SINGD_LOG
env; info for launchers, warn for re-exec'd workers) sets the leveled
logger; worker lines are prefixed [rN]. A mid-run STATUS query of the
elastic control channel returns live telemetry (step, loss, bytes
sent, grad-scaler scale, membership generation) — see PROTOCOL.md.

Regenerating the paper's tables/figures (see DESIGN.md §5):
  cargo bench --bench fig1_vgg_cifar       # Fig. 1 left/center (+ stability)
  cargo bench --bench fig6_transformers    # Fig. 6
  cargo bench --bench fig7_cnn_gnn         # Fig. 7
  cargo bench --bench tab2_iteration_cost  # Table 2
  cargo bench --bench tab3_memory          # Table 3 + Fig. 1 right
  cargo bench --bench hotpath              # §Perf microbenchmarks
  cargo run --release --example train_transformer_e2e   # end-to-end PJRT run
";

/// Run the CLI; returns a process exit code.
pub fn run(argv: &[String]) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            crate::obs_error!("error: {e}\n\n{USAGE}");
            return 2;
        }
    };
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            0
        }
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "gcn" => cmd_gcn(&args),
        "inspect" => cmd_inspect(&args),
        other => {
            crate::obs_error!("unknown subcommand '{other}'\n\n{USAGE}");
            2
        }
    }
}

fn load_config(args: &Args) -> Result<JobConfig, String> {
    let path = args.get("config").ok_or("missing --config".to_string())?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    JobConfig::from_str_toml(&text)
}

fn cmd_train(args: &Args) -> i32 {
    let mut cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            crate::obs_error!("error: {e}");
            return 2;
        }
    };
    if let Some(r) = args.get("ranks") {
        match r.parse::<usize>() {
            Ok(n) if n >= 1 => cfg.ranks = n,
            _ => {
                crate::obs_error!("error: bad --ranks '{r}'");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("strategy") {
        match crate::dist::DistStrategy::parse(s) {
            Some(st) => cfg.dist_strategy = st,
            None => {
                crate::obs_error!("error: bad --strategy '{s}' (replicated | factor-sharded)");
                return 2;
            }
        }
    }
    if let Some(tr) = args.get("transport") {
        match crate::dist::Transport::parse(tr) {
            Some(t) => cfg.transport = t,
            None => {
                crate::obs_error!("error: bad --transport '{tr}' (local | socket)");
                return 2;
            }
        }
    }
    if let Some(al) = args.get("algo") {
        match crate::dist::Algo::parse(al) {
            Some(a) => cfg.algo = a,
            None => {
                crate::obs_error!("error: bad --algo '{al}' (star | ring)");
                return 2;
            }
        }
    }
    if let Some(ov) = args.get("overlap") {
        match crate::dist::parse_overlap(ov) {
            Some(o) => cfg.overlap = o,
            None => {
                crate::obs_error!("error: bad --overlap '{ov}' (0 | 1 | on | off)");
                return 2;
            }
        }
    }
    if let Some(st) = args.get("stream") {
        match crate::dist::parse_overlap(st) {
            Some(s) => cfg.stream = s,
            None => {
                crate::obs_error!("error: bad --stream '{st}' (0 | 1 | on | off)");
                return 2;
            }
        }
    }
    if let Some(k) = args.get("accum-steps") {
        match k.parse::<usize>() {
            Ok(v) => cfg.accum_steps = v.max(1),
            Err(_) => {
                crate::obs_error!("error: bad --accum-steps '{k}' (expected a positive integer)");
                return 2;
            }
        }
    }
    if let Some(w) = args.get("wire-dtype") {
        match crate::numerics::Dtype::parse(w) {
            Some(d) => cfg.wire_dtype = d,
            None => {
                crate::obs_error!("error: bad --wire-dtype '{w}' (f32 | bf16 | fp16)");
                return 2;
            }
        }
    }
    if let Some(p) = args.get("ckpt") {
        cfg.ckpt = Some(p.to_string());
    }
    if let Some(n) = args.get("ckpt-every") {
        match n.parse::<usize>() {
            Ok(v) => cfg.ckpt_every = v,
            Err(_) => {
                crate::obs_error!("error: bad --ckpt-every '{n}' (expected a non-negative integer)");
                return 2;
            }
        }
    }
    if let Some(p) = args.get("resume") {
        cfg.resume = Some(p.to_string());
    }
    if let Some(e) = args.get("elastic") {
        match crate::dist::parse_overlap(e) {
            Some(b) => cfg.elastic = b,
            None => {
                crate::obs_error!("error: bad --elastic '{e}' (0 | 1 | on | off)");
                return 2;
            }
        }
    }
    if let Some(d) = args.get("trace-dir") {
        cfg.trace_dir = Some(d.to_string());
    }
    if let Some(l) = args.get("log") {
        match crate::obs::log::Level::parse(l) {
            Some(level) => cfg.log = Some(level),
            None => {
                crate::obs_error!("error: bad --log '{l}' (error | warn | info | debug)");
                return 2;
            }
        }
    }
    // Workers re-exec'd by the socket launcher inherit the trace dir
    // via the pinned SINGD_TRACE env (transport::launch_workers), so a
    // --trace-dir run traces every rank, not just rank 0.
    if let Some(d) = &cfg.trace_dir {
        if crate::dist::transport::worker_env().is_none() {
            std::env::set_var("SINGD_TRACE", d);
        }
    }
    // Re-validate the elastic preconditions after flag overrides (the
    // TOML layer already checked its own combination) so a bad CLI mix
    // is a clean exit-2, not a driver panic mid-rendezvous.
    if cfg.elastic {
        if cfg.transport != crate::dist::Transport::Socket {
            crate::obs_error!("error: --elastic requires --transport socket");
            return 2;
        }
        if cfg.ckpt.is_none() {
            crate::obs_error!("error: --elastic requires --ckpt (recovery reloads the last checkpoint)");
            return 2;
        }
        if cfg.ckpt_every == 0 {
            crate::obs_error!("error: --elastic requires --ckpt-every >= 1");
            return 2;
        }
        if cfg.ranks < 2 {
            crate::obs_error!("error: --elastic requires --ranks >= 2 (got {})", cfg.ranks);
            return 2;
        }
    }
    // Fail a bad resume path up front with a readable error; the loader
    // itself falls back to the .prev sibling, so accept either existing.
    if let Some(r) = &cfg.resume {
        let prev = format!("{r}.prev");
        if !std::path::Path::new(r).exists() && !std::path::Path::new(&prev).exists() {
            crate::obs_error!("error: --resume checkpoint '{r}' not found (nor '{prev}')");
            return 2;
        }
    }
    // Catch this here (covers --ranks, [dist] ranks and SINGD_RANKS alike)
    // so a bad combination is a clean CLI error, not a driver panic.
    if cfg.ranks > 1 && cfg.batch_size < cfg.ranks {
        crate::obs_error!(
            "error: train.batch_size {} is smaller than ranks {}",
            cfg.batch_size, cfg.ranks
        );
        return 2;
    }
    if cfg.ranks > 1 && cfg.batch_size % cfg.ranks != 0 {
        crate::obs_warn!(
            "warning: train.batch_size {} is not divisible by ranks {}: shards follow \
             the balanced padding rule; training stays deterministic at this world \
             size but forfeits the bitwise rank-invariance guarantee",
            cfg.batch_size, cfg.ranks
        );
    }
    // A worker rank re-exec'd by the socket launcher (SINGD_RANK env
    // contract): run the identical job silently and join the rendezvous
    // inside train_dist; rank 0 — the launching process — owns all
    // reporting and file output. The exit status is the failure channel.
    if crate::dist::transport::worker_env().is_some() {
        let res = exp::run_job(&cfg);
        return if res.diverged { 1 } else { 0 };
    }
    crate::obs_info!(
        "training {} / {} with {} ({}), {} epochs, ranks={} ({}, {}, {}, overlap={}, \
         stream={}, wire={}, accum={})",
        cfg.label,
        cfg.dataset,
        cfg.method.name(),
        cfg.hyper.policy.name(),
        cfg.epochs,
        cfg.ranks,
        cfg.dist_strategy.name(),
        cfg.transport.name(),
        cfg.algo.name(),
        if cfg.overlap { 1 } else { 0 },
        if cfg.stream { 1 } else { 0 },
        cfg.wire_dtype.name(),
        cfg.accum_steps
    );
    let res = exp::run_job(&cfg);
    for r in &res.rows {
        crate::obs_info!(
            "epoch {:>3} step {:>6}  train_loss {:.4}  test_err {:.4}{}",
            r.epoch,
            r.step,
            r.train_loss,
            r.test_err,
            if r.diverged { "  DIVERGED" } else { "" }
        );
    }
    crate::obs_info!(
        "final_err {:.4}  best {:.4}  optimizer_state {} bytes  wall {:.1}s  param_digest {:016x}",
        res.final_test_err, res.best_test_err, res.optimizer_bytes, res.wall_secs, res.param_digest
    );
    if let Some(out) = args.get("out") {
        let csv = res.to_csv(&cfg.label);
        if let Err(e) = std::fs::write(out, csv) {
            crate::obs_error!("write {out}: {e}");
            return 1;
        }
        crate::obs_info!("wrote {out}");
    }
    if res.diverged {
        1
    } else {
        0
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let cfg = match load_config(args) {
        Ok(c) => c,
        Err(e) => {
            crate::obs_error!("error: {e}");
            return 2;
        }
    };
    let trials = args.usize_or("trials", 10);
    let seed = args.usize_or("seed", 0) as u64;
    let results = crate::sweep::random_search(&cfg, &crate::sweep::Space::default(), trials, seed);
    let best = &results[0];
    crate::obs_info!(
        "best: err {:.4} @ lr={:.3e} wd={:.3e} λ={:.3e} β₁={:.3e} α₁={:.1}",
        best.final_err,
        best.hyper.lr,
        best.hyper.weight_decay,
        best.hyper.damping,
        best.hyper.precond_lr,
        best.hyper.riem_momentum
    );
    0
}

fn cmd_gcn(args: &Args) -> i32 {
    let method = Method::parse(args.get("method").unwrap_or("singd:diag"));
    let Some(method) = method else {
        crate::obs_error!("unknown --method");
        return 2;
    };
    let steps = args.usize_or("steps", 200);
    let hp = exp::default_hyper(&method, false);
    let (curve, diverged) = exp::run_gcn(&method, &hp, steps, 7);
    for (t, loss, err) in &curve {
        crate::obs_info!("step {t:>5}  test_loss {loss:.4}  test_err {err:.4}");
    }
    if diverged {
        crate::obs_info!("DIVERGED");
        1
    } else {
        0
    }
}

fn cmd_inspect(args: &Args) -> i32 {
    let s = Structure::parse(args.get("structure").unwrap_or("hier:6")).unwrap_or(Structure::Dense);
    let d = args.usize_or("dim", 12);
    print_structure(s, d);
    0
}

/// Textual rendering of a structure's pattern, its self-outer product, and
/// memory — Figs. 5/8 in terminal form (shared with the gallery example).
pub fn print_structure(s: Structure, d: usize) {
    let mut rng = crate::proptest::Pcg::new(7);
    let m = rng.normal_mat(d, d, 0.5).symmetrize();
    let mut k = crate::structured::proj::proj(s, &m);
    k.axpy(1.0, &SMat::identity(s, d));
    let dense = k.to_dense();
    let kkt = crate::tensor::matmul_a_bt(&dense, &dense);
    let inv = crate::linalg::lu_inverse(&kkt);
    let pat = |m: &crate::tensor::Mat| -> String {
        let mut out = String::new();
        for r in 0..d {
            out.push_str("    ");
            for c in 0..d {
                out.push(if m.at(r, c).abs() > 1e-5 { '■' } else { '·' });
                out.push(' ');
            }
            out.push('\n');
        }
        out
    };
    println!("structure {} (d = {d})", s.name());
    println!("  K pattern ({} stored params, {} bytes fp32):", k.nnz(), k.nnz() * 4);
    println!("{}", pat(&dense));
    println!("  K Kᵀ (approx. inverse Hessian factor) pattern:");
    println!("{}", pat(&kkt));
    if let Some(inv) = inv {
        println!("  (K Kᵀ)⁻¹ (approx. Hessian factor) pattern:");
        println!("{}", pat(&inv));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&sv(&["train", "--config", "x.toml", "--out", "y.csv"])).unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("config"), Some("x.toml"));
        assert_eq!(a.get("out"), Some("y.csv"));
    }

    #[test]
    fn parse_boolean_flag() {
        let a = Args::parse(&sv(&["gcn", "--verbose"])).unwrap();
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn missing_subcommand_errors() {
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn unknown_subcommand_exits_2() {
        assert_eq!(run(&sv(&["frobnicate"])), 2);
    }

    #[test]
    fn help_exits_0() {
        assert_eq!(run(&sv(&["help"])), 0);
    }

    #[test]
    fn train_rejects_bad_dist_flags() {
        let path = std::env::temp_dir().join("singd_cli_dist_test.toml");
        std::fs::write(&path, "[model]\narch = \"mlp\"\n").unwrap();
        let p = path.to_str().unwrap();
        assert_eq!(run(&sv(&["train", "--config", p, "--strategy", "bogus"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--ranks", "0"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--ranks", "x"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--transport", "pigeon"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--algo", "mesh"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--overlap", "sideways"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--stream", "sideways"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--wire-dtype", "int4"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--accum-steps", "x"])), 2);
        // batch_size 32 (default) smaller than the world size → clean
        // error, not a driver assert. (Non-dividing ranks <= batch are
        // allowed: they shard via the balanced padding rule.)
        assert_eq!(run(&sv(&["train", "--config", p, "--ranks", "33"])), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn train_rejects_bad_fault_tolerance_flags() {
        let path = std::env::temp_dir().join("singd_cli_ft_test.toml");
        std::fs::write(&path, "[model]\narch = \"mlp\"\n").unwrap();
        let p = path.to_str().unwrap();
        assert_eq!(run(&sv(&["train", "--config", p, "--ckpt-every", "x"])), 2);
        assert_eq!(run(&sv(&["train", "--config", p, "--elastic", "sideways"])), 2);
        // A resume path that exists neither as-is nor as .prev.
        assert_eq!(
            run(&sv(&["train", "--config", p, "--resume", "/nonexistent/no.ckpt"])),
            2
        );
        // Elastic preconditions, each missing in turn (bare --elastic = on).
        assert_eq!(run(&sv(&["train", "--config", p, "--elastic"])), 2); // not socket
        assert_eq!(
            run(&sv(&["train", "--config", p, "--transport", "socket", "--elastic"])),
            2
        ); // no --ckpt
        assert_eq!(
            run(&sv(&[
                "train", "--config", p, "--transport", "socket", "--elastic", "--ckpt",
                "/tmp/e.ckpt"
            ])),
            2
        ); // ckpt_every = 0
        assert_eq!(
            run(&sv(&[
                "train",
                "--config",
                p,
                "--transport",
                "socket",
                "--elastic",
                "--ckpt",
                "/tmp/e.ckpt",
                "--ckpt-every",
                "2",
                "--ranks",
                "1"
            ])),
            2
        ); // ranks < 2
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn inspect_runs_for_every_structure() {
        for s in ["dense", "diag", "block:3", "tril", "rankk:2", "hier:4", "toeplitz"] {
            let code = run(&sv(&["inspect", "--structure", s, "--dim", "8"]));
            assert_eq!(code, 0, "{s}");
        }
    }
}
