//! `singd` — launcher binary. See `singd help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let argv = if argv.is_empty() { vec!["help".to_string()] } else { argv };
    std::process::exit(singd::cli::run(&argv));
}
