//! A small statistics-reporting benchmark harness (criterion is not
//! available offline; every `[[bench]]` target uses this).
//!
//! Usage inside a `harness = false` bench:
//! ```no_run
//! let mut h = singd::bench::Harness::new("tab2_iteration_cost");
//! h.bench("dense d=256", || { /* work */ });
//! h.finish();
//! ```

use std::time::Instant;

/// Timing statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Collects and prints benchmark results; also dumps a CSV into `results/`.
pub struct Harness {
    label: String,
    results: Vec<Stats>,
    /// Target wall time per case (adaptive iteration count).
    pub target_secs: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

impl Harness {
    pub fn new(label: &str) -> Self {
        crate::obs_info!("== bench: {label} ==");
        Harness { label: label.to_string(), results: Vec::new(), target_secs: 0.5, max_iters: 1000 }
    }

    /// Time `f`, adaptively choosing the iteration count.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Stats {
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_secs / once) as usize).clamp(1, self.max_iters);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: median,
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
        };
        crate::obs_info!(
            "{:<44} {:>12} median {:>12} mean ({} iters)",
            name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            iters
        );
        self.results.push(stats.clone());
        stats
    }

    /// Record an externally-measured value (e.g. bytes) as a result row.
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        crate::obs_info!("{name:<44} {value:>14.2} {unit}");
        self.results.push(Stats {
            name: format!("{name} [{unit}]"),
            iters: 1,
            mean_ns: value,
            median_ns: value,
            min_ns: value,
            max_ns: value,
        });
    }

    /// Print a summary and write `results/<label>.csv`.
    pub fn finish(self) -> Vec<Stats> {
        let mut csv = String::from("name,iters,median_ns,mean_ns,min_ns,max_ns\n");
        for s in &self.results {
            csv.push_str(&format!(
                "{},{},{:.1},{:.1},{:.1},{:.1}\n",
                s.name.replace(',', ";"),
                s.iters,
                s.median_ns,
                s.mean_ns,
                s.min_ns,
                s.max_ns
            ));
        }
        if let Ok(path) = crate::train::write_csv(&format!("{}.csv", self.label), &csv) {
            crate::obs_info!("-- wrote {}", path.display());
        }
        self.results
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_measures_and_orders() {
        let mut h = Harness::new("selftest");
        h.target_secs = 0.02;
        let fast = h.bench("fast", || {
            black_box((0..100).sum::<usize>());
        });
        let slow = h.bench("slow", || {
            black_box((0..100_000).map(|i| i * i).sum::<usize>());
        });
        assert!(slow.median_ns > fast.median_ns);
        let results = h.finish();
        assert_eq!(results.len(), 2);
    }
}
