//! Software low-precision numeric formats and precision policies.
//!
//! This is the numeric-format substrate of the reproduction. The paper's
//! central systems claim is that KFAC's matrix inversion/decomposition is
//! *numerically unstable in BFloat16*, while the inverse-free updates of
//! IKFAC/INGD/SINGD — which consist only of matrix multiplications and
//! subtractions — stay stable. The original experiments ran on CUDA GPUs
//! with PyTorch bf16 tensors; here we reproduce the *format semantics* in
//! software so every experiment is bit-deterministic on CPU:
//!
//! - [`Bf16`] / [`Fp16`]: storage-bit-exact scalar types (u16 payload) with
//!   IEEE round-to-nearest-even conversion from `f32`, correct subnormal /
//!   infinity / NaN behaviour.
//! - [`Dtype`]: a runtime format tag.
//! - [`Policy`]: a compute/storage precision policy matching PyTorch
//!   autocast semantics — ops compute in `f32` and round results to the
//!   storage format. `Policy::quantize_mat` is the single chokepoint all
//!   optimizers route their state updates through.
//! - [`QMat`]: a matrix tagged with a storage dtype whose contents are
//!   always representable in that dtype.
//!
//! The KFAC baseline performs its Cholesky factorization under the same
//! policy and fails in bf16 exactly the way Figure 1/6/7 of the paper
//! report (negative pivots from 8-bit-mantissa rounding of an
//! ill-conditioned `S + λI`).

mod qmat;
mod scalar;
mod scaler;

pub use qmat::QMat;
pub use scalar::{Bf16, Fp16};
pub use scaler::GradScaler;

use crate::tensor::Mat;

/// Runtime numeric format tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// IEEE-754 binary32.
    F32,
    /// bfloat16: 1 sign, 8 exponent, 7 mantissa bits.
    Bf16,
    /// IEEE-754 binary16: 1 sign, 5 exponent, 10 mantissa bits.
    Fp16,
}

impl Dtype {
    /// Bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 | Dtype::Fp16 => 2,
        }
    }

    /// Round an `f32` value to this format (and back to f32 for compute).
    #[inline]
    pub fn round(self, x: f32) -> f32 {
        match self {
            Dtype::F32 => x,
            Dtype::Bf16 => Bf16::from_f32(x).to_f32(),
            Dtype::Fp16 => Fp16::from_f32(x).to_f32(),
        }
    }

    /// Machine epsilon of the format. The half formats use the exact
    /// powers of two (2⁻⁷ / 2⁻¹⁰); a truncated decimal literal here would
    /// be one ulp off the representable value and disagree with
    /// [`Bf16::EPSILON`] / [`Fp16::EPSILON`].
    pub fn eps(self) -> f32 {
        match self {
            Dtype::F32 => f32::EPSILON,
            Dtype::Bf16 => 2f32.powi(-7),
            Dtype::Fp16 => 2f32.powi(-10),
        }
    }

    /// Parse from a config string.
    pub fn parse(s: &str) -> Option<Dtype> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Dtype::F32),
            "bf16" | "bfp16" | "bfloat16" => Some(Dtype::Bf16),
            "f16" | "fp16" | "float16" | "half" => Some(Dtype::Fp16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "fp32",
            Dtype::Bf16 => "bf16",
            Dtype::Fp16 => "fp16",
        }
    }
}

/// Rounding mode applied when quantizing to the storage format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// IEEE round-to-nearest-even (default; what PyTorch/JAX do).
    NearestEven,
    /// Stochastic rounding (ablation; seeded).
    Stochastic { seed: u64 },
}

/// A compute/storage precision policy.
///
/// `compute` is the format intermediate arithmetic is carried out in
/// (always at least as wide as `store` in our experiments); `store` is the
/// format every persisted tensor (optimizer state, preconditioner factors,
/// parameters) is rounded to after each op. `Policy::fp32()` is the
/// reference; `Policy::bf16_mixed()` mirrors the paper's "BFP-16
/// mixed-precision training" setup (f32 compute, bf16 storage).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Policy {
    pub compute: Dtype,
    pub store: Dtype,
    pub rounding: Rounding,
}

impl Policy {
    /// Full-precision reference policy.
    pub fn fp32() -> Policy {
        Policy { compute: Dtype::F32, store: Dtype::F32, rounding: Rounding::NearestEven }
    }

    /// Mixed-precision bf16: f32 accumulate, bf16 storage (paper's BFP-16).
    pub fn bf16_mixed() -> Policy {
        Policy { compute: Dtype::F32, store: Dtype::Bf16, rounding: Rounding::NearestEven }
    }

    /// Pure bf16: even intermediate results are rounded. The harshest
    /// setting; used in the stability ablation.
    pub fn bf16_pure() -> Policy {
        Policy { compute: Dtype::Bf16, store: Dtype::Bf16, rounding: Rounding::NearestEven }
    }

    /// Mixed-precision fp16.
    pub fn fp16_mixed() -> Policy {
        Policy { compute: Dtype::F32, store: Dtype::Fp16, rounding: Rounding::NearestEven }
    }

    /// Parse `"fp32" | "bf16" | "bf16-pure" | "fp16"`.
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" | "f32" => Some(Policy::fp32()),
            "bf16" | "bfp16" | "bf16-mixed" => Some(Policy::bf16_mixed()),
            "bf16-pure" => Some(Policy::bf16_pure()),
            "fp16" | "f16" => Some(Policy::fp16_mixed()),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        if self.compute == self.store {
            format!("{}-pure", self.store.name())
        } else if self.store == Dtype::F32 {
            "fp32".to_string()
        } else {
            self.store.name().to_string()
        }
    }

    /// Round a scalar to the storage format.
    #[inline]
    pub fn q(&self, x: f32) -> f32 {
        match self.rounding {
            Rounding::NearestEven => self.store.round(x),
            Rounding::Stochastic { seed } => stochastic_round(self.store, x, seed),
        }
    }

    /// Round a scalar to the *compute* format (used inside emulated
    /// low-precision kernels when `compute != F32`).
    #[inline]
    pub fn qc(&self, x: f32) -> f32 {
        self.compute.round(x)
    }

    /// Quantize every entry of a matrix to the storage format, in place.
    pub fn quantize_mat(&self, m: &mut Mat) {
        if self.store == Dtype::F32 && matches!(self.rounding, Rounding::NearestEven) {
            return;
        }
        match self.rounding {
            Rounding::NearestEven => {
                let d = self.store;
                m.map_inplace(|x| d.round(x));
            }
            Rounding::Stochastic { seed } => {
                let d = self.store;
                let mut ctr = seed;
                for v in m.data_mut() {
                    ctr = ctr.wrapping_add(0x9e3779b97f4a7c15);
                    *v = stochastic_round_ctr(d, *v, ctr);
                }
            }
        }
    }

    /// Return a quantized copy.
    pub fn quantized(&self, m: &Mat) -> Mat {
        let mut out = m.clone();
        self.quantize_mat(&mut out);
        out
    }

    /// Bytes needed to store a matrix under this policy.
    pub fn stored_bytes(&self, rows: usize, cols: usize) -> usize {
        rows * cols * self.store.bytes()
    }
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

fn stochastic_round(d: Dtype, x: f32, seed: u64) -> f32 {
    stochastic_round_ctr(d, x, splitmix(seed ^ x.to_bits() as u64))
}

/// Stochastic rounding: round to one of the two neighbouring representable
/// values with probability proportional to proximity.
fn stochastic_round_ctr(d: Dtype, x: f32, ctr: u64) -> f32 {
    if d == Dtype::F32 || !x.is_finite() {
        return d.round(x);
    }
    let down = next_representable_toward(d, x, false);
    let up = next_representable_toward(d, x, true);
    if down == up {
        return down;
    }
    let frac = (x - down) / (up - down);
    let u = (splitmix(ctr) >> 40) as f32 / (1u64 << 24) as f32;
    if u < frac {
        up
    } else {
        down
    }
}

/// The nearest representable value of `d` that is `>= x` (up) or `<= x`.
fn next_representable_toward(d: Dtype, x: f32, up: bool) -> f32 {
    let r = d.round(x);
    if (up && r >= x) || (!up && r <= x) {
        return r;
    }
    // Step one ulp of the target format in the needed direction.
    let bits = match d {
        Dtype::Bf16 => Bf16::from_f32(r).bits(),
        Dtype::Fp16 => Fp16::from_f32(r).bits(),
        Dtype::F32 => return r,
    };
    let stepped = step_u16(bits, up);
    match d {
        Dtype::Bf16 => Bf16::from_bits(stepped).to_f32(),
        Dtype::Fp16 => Fp16::from_bits(stepped).to_f32(),
        Dtype::F32 => r,
    }
}

fn step_u16(bits: u16, up: bool) -> u16 {
    let sign = bits & 0x8000;
    let mag = bits & 0x7fff;
    let toward_larger = (sign == 0) == up; // larger value == larger magnitude iff positive
    if toward_larger {
        if mag == 0 && !up {
            return 0x8001; // cross zero downward
        }
        mag.wrapping_add(1) | sign
    } else if mag == 0 {
        if up {
            1
        } else {
            0x8001
        }
    } else {
        (mag - 1) | sign
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_round_identity_for_f32() {
        assert_eq!(Dtype::F32.round(1.234567), 1.234567);
    }

    #[test]
    fn bf16_round_drops_mantissa() {
        // 1 + 2^-8 is not representable in bf16 (7 mantissa bits) and
        // rounds to 1.0 under nearest-even.
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(Dtype::Bf16.round(x), 1.0);
        // 1 + 2^-7 is exactly representable.
        let y = 1.0 + 2f32.powi(-7);
        assert_eq!(Dtype::Bf16.round(y), y);
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(Policy::parse("fp32"), Some(Policy::fp32()));
        assert_eq!(Policy::parse("BF16"), Some(Policy::bf16_mixed()));
        assert_eq!(Policy::parse("bf16-pure"), Some(Policy::bf16_pure()));
        assert_eq!(Policy::parse("nope"), None);
    }

    #[test]
    fn quantize_mat_bf16_reduces_precision() {
        let m = Mat::from_vec(1, 3, vec![1.0, 1.0 + 2f32.powi(-9), 3.141592653]);
        let q = Policy::bf16_mixed().quantized(&m);
        assert_eq!(q.at(0, 0), 1.0);
        assert_eq!(q.at(0, 1), 1.0); // rounded away
        assert!((q.at(0, 2) - 3.141592653).abs() < 0.02);
    }

    #[test]
    fn fp32_quantize_is_noop() {
        let m = Mat::from_vec(1, 2, vec![1.23456789, -9.87654321]);
        assert_eq!(Policy::fp32().quantized(&m), m);
    }

    #[test]
    fn stochastic_rounding_is_unbiased_ish() {
        // Value exactly halfway between two bf16 neighbours: the mean of
        // many stochastic roundings should approach the value itself.
        let x = 1.0 + 0.5 * 2f32.powi(-7);
        let mut acc = 0.0f64;
        let n = 4000;
        for i in 0..n {
            acc += stochastic_round_ctr(Dtype::Bf16, x, i as u64) as f64;
        }
        let mean = acc / n as f64;
        assert!((mean - x as f64).abs() < 2e-3, "mean {mean} vs {x}");
    }

    #[test]
    fn stochastic_round_hits_only_neighbours() {
        let x = 0.3f32;
        let lo = next_representable_toward(Dtype::Bf16, x, false);
        let hi = next_representable_toward(Dtype::Bf16, x, true);
        assert!(lo <= x && x <= hi && lo < hi);
        for i in 0..200u64 {
            let r = stochastic_round_ctr(Dtype::Bf16, x, i);
            assert!(r == lo || r == hi, "{r} not in {{{lo},{hi}}}");
        }
    }

    #[test]
    fn eps_ordering() {
        assert!(Dtype::F32.eps() < Dtype::Fp16.eps());
        assert!(Dtype::Fp16.eps() < Dtype::Bf16.eps());
    }

    #[test]
    fn eps_matches_scalar_epsilon_exactly() {
        // Satellite bugfix: the fp16 eps literal used to be the truncated
        // 0.00097656 (≠ 2⁻¹⁰ = 0.0009765625), one ulp off the scalar
        // constant. Both formats must agree bitwise with their scalar type.
        assert_eq!(Dtype::Bf16.eps().to_bits(), Bf16::EPSILON.to_f32().to_bits());
        assert_eq!(Dtype::Fp16.eps().to_bits(), Fp16::EPSILON.to_f32().to_bits());
        assert_eq!(Dtype::Fp16.eps(), 0.0009765625);
    }

    #[test]
    fn stored_bytes_accounting() {
        assert_eq!(Policy::fp32().stored_bytes(10, 10), 400);
        assert_eq!(Policy::bf16_mixed().stored_bytes(10, 10), 200);
    }
}
