//! Bit-exact software `bfloat16` and IEEE `binary16` scalars.
//!
//! Conversions implement round-to-nearest-even, matching hardware bf16/fp16
//! units (and `torch.bfloat16` / `jnp.bfloat16` semantics), including
//! subnormals, overflow-to-infinity, and NaN propagation.

/// bfloat16: the top 16 bits of an IEEE binary32.
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct Bf16(u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3f80);
    pub const INFINITY: Bf16 = Bf16(0x7f80);
    pub const NEG_INFINITY: Bf16 = Bf16(0xff80);
    /// Largest finite bf16 (≈ 3.3895e38).
    pub const MAX: Bf16 = Bf16(0x7f7f);
    /// Smallest positive normal (2^-126).
    pub const MIN_POSITIVE: Bf16 = Bf16(0x0080);
    /// Machine epsilon: 2^-7.
    pub const EPSILON: Bf16 = Bf16(0x3c00);

    /// Convert from f32 with round-to-nearest-even.
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet NaN, preserve sign + payload top bits; ensure non-zero mantissa.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x8000u32;
        let lower = bits & 0xffff;
        let mut upper = (bits >> 16) as u16;
        if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
            upper = upper.wrapping_add(1); // may carry into exponent -> correct (rounds to inf)
        }
        Bf16(upper)
    }

    /// Widen to f32 (exact).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn from_bits(bits: u16) -> Bf16 {
        Bf16(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7f80) == 0x7f80 && (self.0 & 0x007f) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7fff) == 0x7f80
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7f80) != 0x7f80
    }
}

/// IEEE-754 binary16 (half precision).
#[derive(Clone, Copy, PartialEq, Debug, Default)]
pub struct Fp16(u16);

impl Fp16 {
    pub const ZERO: Fp16 = Fp16(0);
    pub const ONE: Fp16 = Fp16(0x3c00);
    pub const INFINITY: Fp16 = Fp16(0x7c00);
    pub const NEG_INFINITY: Fp16 = Fp16(0xfc00);
    /// Largest finite fp16 (= 65504).
    pub const MAX: Fp16 = Fp16(0x7bff);
    /// Machine epsilon: 2^-10.
    pub const EPSILON: Fp16 = Fp16(0x1400);

    /// Convert from f32 with round-to-nearest-even (handles subnormals,
    /// overflow to infinity, NaN payloads).
    pub fn from_f32(x: f32) -> Fp16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xff) as i32;
        let man = bits & 0x007f_ffff;

        if exp == 0xff {
            // Inf or NaN.
            if man == 0 {
                return Fp16(sign | 0x7c00);
            }
            return Fp16(sign | 0x7c00 | ((man >> 13) as u16) | 1);
        }

        // Unbiased exponent.
        let e = exp - 127;
        if e > 15 {
            // Overflow -> infinity.
            return Fp16(sign | 0x7c00);
        }
        if e >= -14 {
            // Normal range.
            let half_exp = ((e + 15) as u32) << 10;
            let half_man = man >> 13;
            let rest = man & 0x1fff;
            let mut h = sign as u32 | half_exp | half_man;
            // Round to nearest even.
            if rest > 0x1000 || (rest == 0x1000 && (h & 1) == 1) {
                h += 1; // may carry into exponent; that is correct rounding
            }
            return Fp16(h as u16);
        }
        if e < -25 {
            // Underflow to signed zero.
            return Fp16(sign);
        }
        // Subnormal half: value = 1.man · 2^e = half_man · 2^-24 with
        // half_man = full_man · 2^(e+1) and full_man holding 24 bits.
        let full_man = man | 0x0080_0000; // implicit leading 1
        let shift = (-e - 1) as u32; // e ∈ [-25, -15] → shift ∈ [14, 24]
        let half_man = full_man >> shift;
        let rest = full_man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = sign as u32 | half_man;
        if rest > halfway || (rest == halfway && (h & 1) == 1) {
            h += 1;
        }
        Fp16(h as u16)
    }

    /// Widen to f32 (exact).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1f) as u32;
        let man = (self.0 & 0x03ff) as u32;
        let bits = if exp == 0 {
            if man == 0 {
                sign // ±0
            } else {
                // Subnormal: value = man * 2^-24 (exact in f32).
                let v = man as f32 * 2f32.powi(-24);
                return if sign != 0 { -v } else { v };
            }
        } else if exp == 0x1f {
            sign | 0x7f80_0000 | (man << 13)
        } else {
            sign | ((exp + 112) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    #[inline]
    pub fn bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn from_bits(bits: u16) -> Fp16 {
        Fp16(bits)
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7c00) == 0x7c00 && (self.0 & 0x03ff) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7fff) == 0x7c00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7c00) != 0x7c00
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_exact_values_roundtrip() {
        // All exactly representable in bf16 (≤ 8 significant bits).
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1.5, 3.0, 256.0, 2f32.powi(100)] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "roundtrip {v}");
        }
        // Round-trip is idempotent for arbitrary values.
        for &v in &[1e30f32, -1e-30, 3.14159, 0.1] {
            let once = Bf16::from_f32(v).to_f32();
            assert_eq!(Bf16::from_f32(once).to_f32(), once, "idempotent {v}");
        }
    }

    #[test]
    fn bf16_round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between 1.0 and 1.0+2^-7.
        // Nearest-even picks 1.0 (even mantissa).
        assert_eq!(Bf16::from_f32(1.0 + 2f32.powi(-8)).to_f32(), 1.0);
        // (1 + 2^-7) + 2^-8 is halfway; nearest-even picks 1+2^-6 side?
        // mantissa of 1+2^-7 is odd (…0000001) so it rounds up.
        let x = 1.0 + 2f32.powi(-7) + 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0 + 2f32.powi(-6));
        // Slightly above halfway rounds up.
        assert_eq!(Bf16::from_f32(1.0 + 2f32.powi(-8) + 1e-6).to_f32(), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn bf16_overflow_to_infinity() {
        // Largest finite bf16 is ≈3.39e38; nudging above must round to inf.
        let b = Bf16::from_f32(f32::MAX);
        assert!(b.is_infinite());
    }

    #[test]
    fn bf16_nan_propagates() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn bf16_matches_truncation_plus_rounding_model() {
        // Against an independent reference: round by adding the rounding
        // bias then truncating (the classic "round half to even" trick).
        let reference = |x: f32| -> f32 {
            if x.is_nan() {
                return f32::NAN;
            }
            let bits = x.to_bits();
            let bias = 0x7fffu32 + ((bits >> 16) & 1);
            f32::from_bits(((bits + bias) >> 16) << 16)
        };
        let mut seed = 0x12345u32;
        for _ in 0..10_000 {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            let x = f32::from_bits(seed & 0x7fff_ffff);
            if !x.is_finite() {
                continue;
            }
            let ours = Bf16::from_f32(x).to_f32();
            let theirs = reference(x);
            assert!(
                ours == theirs || (ours.is_infinite() && theirs.is_infinite()),
                "x={x:e}: ours={ours:e} ref={theirs:e}"
            );
        }
    }

    #[test]
    fn fp16_known_values() {
        assert_eq!(Fp16::from_f32(1.0).bits(), 0x3c00);
        assert_eq!(Fp16::from_f32(-2.0).bits(), 0xc000);
        assert_eq!(Fp16::from_f32(65504.0).bits(), 0x7bff);
        assert!(Fp16::from_f32(65520.0).is_infinite()); // rounds over MAX
        assert_eq!(Fp16::from_f32(0.0).bits(), 0x0000);
    }

    #[test]
    fn fp16_roundtrip_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 1024.0, 0.09997559] {
            let h = Fp16::from_f32(v);
            let back = h.to_f32();
            let again = Fp16::from_f32(back);
            assert_eq!(h.bits(), again.bits(), "double-roundtrip {v}");
        }
    }

    #[test]
    fn fp16_subnormals() {
        // Smallest positive subnormal: 2^-24.
        let tiny = 2f32.powi(-24);
        let h = Fp16::from_f32(tiny);
        assert_eq!(h.bits(), 1);
        assert_eq!(h.to_f32(), tiny);
        // Underflow below half the smallest subnormal -> zero.
        assert_eq!(Fp16::from_f32(2f32.powi(-26)).bits(), 0);
    }

    #[test]
    fn fp16_roundtrip_is_idempotent_random() {
        let mut seed = 0xdeadbeefu32;
        for _ in 0..10_000 {
            seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
            let x = f32::from_bits(seed);
            if x.is_nan() {
                continue;
            }
            let once = Fp16::from_f32(x).to_f32();
            let twice = Fp16::from_f32(once).to_f32();
            assert!(once == twice || (once.is_nan() && twice.is_nan()), "x={x:e}");
        }
    }

    #[test]
    fn fp16_nan_and_inf() {
        assert!(Fp16::from_f32(f32::NAN).is_nan());
        assert!(Fp16::from_f32(f32::INFINITY).is_infinite());
        assert!(Fp16::from_f32(f32::NEG_INFINITY).is_infinite());
        assert!(Fp16::from_f32(1e10).is_infinite());
    }

    // =================================================================
    // Exhaustive 65536-bit-pattern conformance (ISSUE 8 satellite).
    // Both half formats are now a storage format ([`super::super::QMat`])
    // *and* a wire format, so every one of the 2^16 payloads must widen
    // and re-narrow faithfully — a single wrong pattern would silently
    // corrupt checkpoints and collectives.

    #[test]
    fn bf16_all_65536_bit_patterns_widen_and_renarrow_bitwise() {
        // `to_f32` is exact, so every non-NaN pattern is representable
        // and nearest-even re-narrowing must be the bitwise identity.
        // NaN payloads need not round-trip bitwise (`from_f32` quiets
        // them), but the class must survive, as must the sign/inf/finite
        // classes of everything else.
        for bits in 0..=u16::MAX {
            let h = Bf16::from_bits(bits);
            let w = h.to_f32();
            let back = Bf16::from_f32(w);
            if h.is_nan() {
                assert!(w.is_nan(), "bf16 {bits:#06x}: widened NaN lost");
                assert!(back.is_nan(), "bf16 {bits:#06x}: re-narrowed NaN lost");
            } else {
                assert_eq!(back.bits(), bits, "bf16 {bits:#06x} -> {w:e}");
                assert_eq!(h.is_infinite(), w.is_infinite(), "bf16 {bits:#06x}: inf class");
                assert_eq!(h.is_finite(), w.is_finite(), "bf16 {bits:#06x}: finite class");
                assert_eq!(
                    bits & 0x8000 != 0,
                    w.is_sign_negative(),
                    "bf16 {bits:#06x}: sign"
                );
            }
        }
    }

    #[test]
    fn fp16_all_65536_bit_patterns_widen_and_renarrow_bitwise() {
        for bits in 0..=u16::MAX {
            let h = Fp16::from_bits(bits);
            let w = h.to_f32();
            let back = Fp16::from_f32(w);
            if h.is_nan() {
                assert!(w.is_nan(), "fp16 {bits:#06x}: widened NaN lost");
                assert!(back.is_nan(), "fp16 {bits:#06x}: re-narrowed NaN lost");
            } else {
                assert_eq!(back.bits(), bits, "fp16 {bits:#06x} -> {w:e}");
                assert_eq!(h.is_infinite(), w.is_infinite(), "fp16 {bits:#06x}: inf class");
                assert_eq!(h.is_finite(), w.is_finite(), "fp16 {bits:#06x}: finite class");
                assert_eq!(
                    bits & 0x8000 != 0,
                    w.is_sign_negative() || w == 0.0 && bits == 0x8000,
                    "fp16 {bits:#06x}: sign"
                );
            }
        }
    }

    #[test]
    fn bf16_narrowing_matches_bias_trick_reference_on_every_high_half() {
        // Independent nearest-even reference (add the rounding bias,
        // truncate), swept over all 2^16 f32 high halves × low-half
        // patterns straddling the rounding boundary: exact (0x0000),
        // just-below-half (0x7fff), the tie (0x8000), just-above-half
        // (0x8001), and all-ones (0xffff).
        let reference = |x: f32| -> u16 {
            let bits = x.to_bits();
            let bias = 0x7fffu32 + ((bits >> 16) & 1);
            (bits.wrapping_add(bias) >> 16) as u16
        };
        for hi in 0..=u16::MAX {
            for lo in [0x0000u32, 0x7fff, 0x8000, 0x8001, 0xffff] {
                let x = f32::from_bits(((hi as u32) << 16) | lo);
                if x.is_nan() {
                    continue; // NaN narrowing is class-, not bit-, specified
                }
                assert_eq!(
                    Bf16::from_f32(x).bits(),
                    reference(x),
                    "hi={hi:#06x} lo={lo:#06x} x={x:e}"
                );
            }
        }
    }

    #[test]
    fn fp16_every_rounding_boundary_is_ties_to_even() {
        // For every adjacent pair of same-sign finite fp16 magnitudes,
        // the exact f32 midpoint (representable: ≤ 12-bit significand)
        // must narrow to the even-mantissa neighbour, and one f32 ulp to
        // either side must narrow to the strictly nearer neighbour.
        // Sweeps normals, subnormals, the subnormal/normal seam and the
        // zero boundary, for both signs — 2 × 31743 boundaries.
        for sign in [0u16, 0x8000] {
            for mag in 0..Fp16::MAX.bits() {
                let lo = Fp16::from_bits(sign | mag);
                let hi = Fp16::from_bits(sign | (mag + 1));
                let mid = 0.5 * (lo.to_f32() + hi.to_f32());
                let want_even = if mag & 1 == 0 { lo } else { hi };
                assert_eq!(
                    Fp16::from_f32(mid).bits(),
                    want_even.bits(),
                    "tie at {:#06x}",
                    sign | mag
                );
                // from_bits(±1) on the midpoint moves one f32 ulp toward /
                // away from zero in magnitude — lo is always the
                // smaller-magnitude neighbour.
                let mb = mid.to_bits();
                assert_eq!(
                    Fp16::from_f32(f32::from_bits(mb - 1)).bits(),
                    lo.bits(),
                    "below tie at {:#06x}",
                    sign | mag
                );
                assert_eq!(
                    Fp16::from_f32(f32::from_bits(mb + 1)).bits(),
                    hi.bits(),
                    "above tie at {:#06x}",
                    sign | mag
                );
            }
        }
    }
}
