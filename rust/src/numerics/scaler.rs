//! Dynamic gradient (loss) scaling for fp16 training.
//!
//! The paper notes (§2.1) that in 16-bit training "over- or underflow can
//! be an issue when calculating `G_l` and the gradient `g_l` has to be
//! rescaled to improve stability". bf16 shares f32's exponent range, but
//! fp16 has a 5-bit exponent: per-sample gradients routinely underflow to
//! zero (killing the Kronecker `G` factor) or overflow at 65 504. This is
//! the standard AMP-style dynamic scaler: multiply the loss/gradients by
//! `scale` before quantization, unscale before the optimizer step, halve
//! on overflow, double after a streak of clean steps.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct GradScaler {
    scale: f32,
    growth_factor: f32,
    backoff_factor: f32,
    growth_interval: usize,
    clean_steps: usize,
    /// Number of steps skipped due to non-finite scaled gradients.
    pub skipped: usize,
}

impl Default for GradScaler {
    fn default() -> Self {
        GradScaler {
            scale: 65536.0,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            growth_interval: 200,
            clean_steps: 0,
            skipped: 0,
        }
    }
}

impl GradScaler {
    pub fn new(initial_scale: f32) -> Self {
        GradScaler { scale: initial_scale, ..Default::default() }
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Scale a gradient (before 16-bit quantization).
    pub fn scale_mat(&self, g: &Mat) -> Mat {
        g.scale(self.scale)
    }

    /// Snapshot the schedule state for checkpointing:
    /// `(scale, clean_steps, skipped)`. Resume restores it with
    /// [`GradScaler::restore`]; without this, a resumed fp16 run would
    /// reset the scale to 65536 and break bitwise resume determinism.
    pub fn state(&self) -> (f32, usize, usize) {
        (self.scale, self.clean_steps, self.skipped)
    }

    /// Restore a checkpointed schedule snapshot (see [`GradScaler::state`]).
    pub fn restore(&mut self, scale: f32, clean_steps: usize, skipped: usize) {
        self.scale = scale;
        self.clean_steps = clean_steps;
        self.skipped = skipped;
    }

    /// Advance the scale schedule given this step's overflow verdict:
    /// back off (and count a skip) on overflow, otherwise extend the clean
    /// streak and grow at the interval. Split from the unscaling so
    /// distributed drivers can feed it the OR-reduced overflow flag — the
    /// schedule then advances identically on every rank.
    pub fn update(&mut self, overflow: bool) {
        if overflow {
            self.scale = (self.scale * self.backoff_factor).max(1.0);
            self.clean_steps = 0;
            self.skipped += 1;
            // Observability only — nothing below affects the decision.
            crate::obs_count!("scaler.overflows", 1);
            if crate::obs::trace::active() {
                crate::obs::trace::instant(
                    "scaler_overflow",
                    "scaler",
                    vec![("scale", crate::obs::trace::ArgVal::F(self.scale as f64))],
                );
            }
        } else {
            self.clean_steps += 1;
            if self.clean_steps >= self.growth_interval {
                self.scale *= self.growth_factor;
                self.clean_steps = 0;
                crate::obs_count!("scaler.growths", 1);
                if crate::obs::trace::active() {
                    crate::obs::trace::instant(
                        "scaler_growth",
                        "scaler",
                        vec![("scale", crate::obs::trace::ArgVal::F(self.scale as f64))],
                    );
                }
            }
        }
        crate::obs::metrics::set_scale(self.scale);
    }

    /// Unscale gradients in place and report whether the step is usable.
    /// On any non-finite entry the step must be skipped and the scale is
    /// backed off; on success the clean-streak counter advances and the
    /// scale may grow. Serial convenience wrapper over the
    /// detect-then-[`GradScaler::update`] split.
    pub fn unscale_and_update(&mut self, grads: &mut [Mat]) -> bool {
        let inv = 1.0 / self.scale;
        let mut finite = true;
        for g in grads.iter() {
            finite &= !g.has_nonfinite();
        }
        if !finite {
            self.update(true);
            return false;
        }
        for g in grads.iter_mut() {
            g.map_inplace(|x| x * inv);
        }
        self.update(false);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Dtype;

    #[test]
    fn unscale_restores_magnitude() {
        let mut s = GradScaler::new(1024.0);
        let g = Mat::from_vec(1, 2, vec![0.5, -0.25]);
        let mut scaled = [s.scale_mat(&g)];
        assert_eq!(scaled[0].at(0, 0), 512.0);
        assert!(s.unscale_and_update(&mut scaled));
        crate::proptest::assert_mat_close(&scaled[0], &g, 1e-6, "unscale");
    }

    #[test]
    fn overflow_backs_off_and_skips() {
        let mut s = GradScaler::new(1024.0);
        let mut bad = [Mat::from_vec(1, 1, vec![f32::INFINITY])];
        assert!(!s.unscale_and_update(&mut bad));
        assert_eq!(s.scale(), 512.0);
        assert_eq!(s.skipped, 1);
    }

    #[test]
    fn growth_after_clean_interval() {
        let mut s = GradScaler { growth_interval: 3, ..GradScaler::new(8.0) };
        for _ in 0..3 {
            let mut g = [Mat::ones(1, 1)];
            assert!(s.unscale_and_update(&mut g));
        }
        assert_eq!(s.scale(), 16.0);
    }

    #[test]
    fn growth_interval_boundary_is_exact() {
        // Growth happens on the Nth consecutive clean step, not before,
        // and the streak counter resets so the next growth needs another
        // full interval.
        let mut s = GradScaler { growth_interval: 3, ..GradScaler::new(8.0) };
        for i in 0..2 {
            let mut g = [Mat::ones(1, 1)];
            assert!(s.unscale_and_update(&mut g));
            assert_eq!(s.scale(), 8.0, "no growth after {} clean steps", i + 1);
        }
        let mut g = [Mat::ones(1, 1)];
        assert!(s.unscale_and_update(&mut g));
        assert_eq!(s.scale(), 16.0, "growth exactly at the interval");
        let mut g = [Mat::ones(1, 1)];
        assert!(s.unscale_and_update(&mut g));
        assert_eq!(s.scale(), 16.0, "streak must reset after growth");
    }

    #[test]
    fn overflow_resets_the_clean_streak() {
        let mut s = GradScaler { growth_interval: 3, ..GradScaler::new(8.0) };
        for _ in 0..2 {
            let mut g = [Mat::ones(1, 1)];
            assert!(s.unscale_and_update(&mut g));
        }
        let mut bad = [Mat::from_vec(1, 1, vec![f32::NAN])];
        assert!(!s.unscale_and_update(&mut bad));
        assert_eq!(s.scale(), 4.0);
        // Two clean steps after the overflow: still no growth (streak
        // restarted at zero, interval is 3).
        for _ in 0..2 {
            let mut g = [Mat::ones(1, 1)];
            assert!(s.unscale_and_update(&mut g));
        }
        assert_eq!(s.scale(), 4.0);
        let mut g = [Mat::ones(1, 1)];
        assert!(s.unscale_and_update(&mut g));
        assert_eq!(s.scale(), 8.0);
    }

    #[test]
    fn backoff_floors_at_one() {
        let mut s = GradScaler::new(1.5);
        for _ in 0..4 {
            let mut bad = [Mat::from_vec(1, 1, vec![f32::INFINITY])];
            assert!(!s.unscale_and_update(&mut bad));
        }
        assert_eq!(s.scale(), 1.0, "scale must never fall below 1");
        assert_eq!(s.skipped, 4);
    }

    #[test]
    fn skipped_step_leaves_optimizer_state_untouched() {
        // The AMP contract: when unscale reports overflow the caller
        // skips `opt.step`, so neither parameters nor momenta move and
        // the next clean step proceeds from unchanged state.
        use crate::optim::{Hyper, KronStats, Method, Optimizer};
        let hp = Hyper { lr: 0.1, momentum: 0.9, weight_decay: 0.0, ..Hyper::default() };
        let mut opt = Method::Sgd.build(&[(2, 3)], &hp);
        let mut params = [Mat::ones(2, 3)];
        let stats = [KronStats { a: Mat::zeros(1, 3), g: Mat::zeros(1, 2) }];
        // One clean step to give the momentum buffer a nonzero value.
        let mut scaler = GradScaler::new(1024.0);
        let mut grads = [scaler.scale_mat(&Mat::ones(2, 3))];
        assert!(scaler.unscale_and_update(&mut grads));
        opt.step(0, &mut params, &grads, &stats);
        let state_before = opt.state_vectors();
        let params_before = params[0].clone();
        // Overflowed step: unscale fails → the step is skipped.
        let mut bad = [Mat::from_vec(2, 3, vec![f32::INFINITY; 6])];
        assert!(!scaler.unscale_and_update(&mut bad));
        assert_eq!(scaler.skipped, 1);
        assert_eq!(opt.state_vectors(), state_before, "momentum must be untouched");
        assert_eq!(params[0], params_before, "params must be untouched");
        // Training resumes cleanly at the backed-off scale.
        let mut grads = [scaler.scale_mat(&Mat::ones(2, 3))];
        assert!(scaler.unscale_and_update(&mut grads));
        opt.step(1, &mut params, &grads, &stats);
        assert_ne!(opt.state_vectors(), state_before);
    }

    #[test]
    fn state_restore_roundtrips_and_resumes_the_schedule() {
        let mut s = GradScaler::new(2048.0);
        s.update(true); // backoff → 1024, skipped = 1
        s.update(false); // clean streak = 1
        let (scale, clean, skipped) = s.state();
        assert_eq!((scale, clean, skipped), (1024.0, 1, 1));
        let mut resumed = GradScaler::default();
        resumed.restore(scale, clean, skipped);
        assert_eq!(resumed.state(), s.state());
        // The restored scaler continues the identical schedule.
        s.update(false);
        resumed.update(false);
        assert_eq!(resumed.state(), s.state());
    }

    #[test]
    fn update_split_matches_unscale_and_update() {
        // The detect/apply split must drive the same schedule as the
        // serial convenience wrapper.
        let mut a = GradScaler { growth_interval: 2, ..GradScaler::new(64.0) };
        let mut b = GradScaler { growth_interval: 2, ..GradScaler::new(64.0) };
        for &overflow in &[false, true, false, false, false, true] {
            let mut g = if overflow {
                [Mat::from_vec(1, 1, vec![f32::INFINITY])]
            } else {
                [Mat::ones(1, 1)]
            };
            assert_eq!(a.unscale_and_update(&mut g), !overflow);
            b.update(overflow);
            assert_eq!(a.state(), b.state());
        }
    }

    #[test]
    fn rescues_fp16_underflow() {
        // A gradient of 1e-7 lands deep in fp16's subnormal range (spacing
        // 2⁻²⁴ ≈ 6e-8: only ~1 significant bit); scaled by 65536 it moves
        // into the normal range and unscaling recovers it in f32.
        let g = 1e-7f32;
        let naive = Dtype::Fp16.round(g);
        assert!((naive - g).abs() / g > 0.05, "fp16 mangles tiny grads: {naive}");
        let mut s = GradScaler::new(65536.0);
        let scaled = Dtype::Fp16.round(g * s.scale());
        let mut grads = [Mat::from_vec(1, 1, vec![scaled])];
        assert!(s.unscale_and_update(&mut grads));
        let recovered = grads[0].at(0, 0);
        assert!((recovered - g).abs() / g < 1e-3, "recovered {recovered}");
    }
}
