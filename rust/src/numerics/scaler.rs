//! Dynamic gradient (loss) scaling for fp16 training.
//!
//! The paper notes (§2.1) that in 16-bit training "over- or underflow can
//! be an issue when calculating `G_l` and the gradient `g_l` has to be
//! rescaled to improve stability". bf16 shares f32's exponent range, but
//! fp16 has a 5-bit exponent: per-sample gradients routinely underflow to
//! zero (killing the Kronecker `G` factor) or overflow at 65 504. This is
//! the standard AMP-style dynamic scaler: multiply the loss/gradients by
//! `scale` before quantization, unscale before the optimizer step, halve
//! on overflow, double after a streak of clean steps.

use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct GradScaler {
    scale: f32,
    growth_factor: f32,
    backoff_factor: f32,
    growth_interval: usize,
    clean_steps: usize,
    /// Number of steps skipped due to non-finite scaled gradients.
    pub skipped: usize,
}

impl Default for GradScaler {
    fn default() -> Self {
        GradScaler {
            scale: 65536.0,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            growth_interval: 200,
            clean_steps: 0,
            skipped: 0,
        }
    }
}

impl GradScaler {
    pub fn new(initial_scale: f32) -> Self {
        GradScaler { scale: initial_scale, ..Default::default() }
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Scale a gradient (before 16-bit quantization).
    pub fn scale_mat(&self, g: &Mat) -> Mat {
        g.scale(self.scale)
    }

    /// Unscale gradients in place and report whether the step is usable.
    /// On any non-finite entry the step must be skipped and the scale is
    /// backed off; on success the clean-streak counter advances and the
    /// scale may grow.
    pub fn unscale_and_update(&mut self, grads: &mut [Mat]) -> bool {
        let inv = 1.0 / self.scale;
        let mut finite = true;
        for g in grads.iter() {
            finite &= !g.has_nonfinite();
        }
        if !finite {
            self.scale = (self.scale * self.backoff_factor).max(1.0);
            self.clean_steps = 0;
            self.skipped += 1;
            return false;
        }
        for g in grads.iter_mut() {
            g.map_inplace(|x| x * inv);
        }
        self.clean_steps += 1;
        if self.clean_steps >= self.growth_interval {
            self.scale *= self.growth_factor;
            self.clean_steps = 0;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Dtype;

    #[test]
    fn unscale_restores_magnitude() {
        let mut s = GradScaler::new(1024.0);
        let g = Mat::from_vec(1, 2, vec![0.5, -0.25]);
        let mut scaled = [s.scale_mat(&g)];
        assert_eq!(scaled[0].at(0, 0), 512.0);
        assert!(s.unscale_and_update(&mut scaled));
        crate::proptest::assert_mat_close(&scaled[0], &g, 1e-6, "unscale");
    }

    #[test]
    fn overflow_backs_off_and_skips() {
        let mut s = GradScaler::new(1024.0);
        let mut bad = [Mat::from_vec(1, 1, vec![f32::INFINITY])];
        assert!(!s.unscale_and_update(&mut bad));
        assert_eq!(s.scale(), 512.0);
        assert_eq!(s.skipped, 1);
    }

    #[test]
    fn growth_after_clean_interval() {
        let mut s = GradScaler { growth_interval: 3, ..GradScaler::new(8.0) };
        for _ in 0..3 {
            let mut g = [Mat::ones(1, 1)];
            assert!(s.unscale_and_update(&mut g));
        }
        assert_eq!(s.scale(), 16.0);
    }

    #[test]
    fn rescues_fp16_underflow() {
        // A gradient of 1e-7 lands deep in fp16's subnormal range (spacing
        // 2⁻²⁴ ≈ 6e-8: only ~1 significant bit); scaled by 65536 it moves
        // into the normal range and unscaling recovers it in f32.
        let g = 1e-7f32;
        let naive = Dtype::Fp16.round(g);
        assert!((naive - g).abs() / g > 0.05, "fp16 mangles tiny grads: {naive}");
        let mut s = GradScaler::new(65536.0);
        let scaled = Dtype::Fp16.round(g * s.scale());
        let mut grads = [Mat::from_vec(1, 1, vec![scaled])];
        assert!(s.unscale_and_update(&mut grads));
        let recovered = grads[0].at(0, 0);
        assert!((recovered - g).abs() / g < 1e-3, "recovered {recovered}");
    }
}
