//! [`QMat`]: a matrix tagged with a storage dtype, physically stored in
//! that dtype.
//!
//! `Policy::quantize_mat` guarantees *values* are representable in the
//! storage format but keeps the 4-byte `f32` image in memory — fine for
//! studying rounding behaviour, wrong for studying memory. `QMat` closes
//! that gap: under a half-precision policy the payload is the narrowed
//! `u16` words themselves, so `bytes()` is the real footprint and the
//! Table-3 memory accounting measures actual allocations instead of a
//! formula. Under an `f32` policy the payload stays a plain [`Mat`] and
//! every operation is the identity — zero behaviour change for the
//! full-precision reference path.
//!
//! Widening is exact (both half formats embed losslessly in f32), so
//! `store` → `widen` round-trips bitwise for already-quantized values and
//! all existing bitwise contracts (checkpoint state vectors, serial vs
//! distributed digests) hold unchanged.
//!
//! The matmul entry points ([`QMat::matmul_qa`] / [`QMat::matmul_qb`])
//! widen at *pack time* inside `tensor::matmul` — the panel packers copy
//! into contiguous strips anyway, so the u16→f32 conversion rides that
//! copy and the 4×16 microkernel keeps accumulating in f32. The result is
//! bitwise identical to widening the whole operand first, without ever
//! materializing the 4-byte copy.

use super::{Bf16, Dtype, Fp16, Policy};
use crate::tensor::{matmul, matmul_a_wb, matmul_wa_b, Mat};

fn widen_bf16(bits: u16) -> f32 {
    Bf16::from_bits(bits).to_f32()
}

fn widen_fp16(bits: u16) -> f32 {
    Fp16::from_bits(bits).to_f32()
}

/// The pack-time widening function for a half dtype.
fn widen_fn(dtype: Dtype) -> fn(u16) -> f32 {
    match dtype {
        Dtype::Bf16 => widen_bf16,
        Dtype::Fp16 => widen_fp16,
        Dtype::F32 => unreachable!("f32 payloads are stored as Mat"),
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    /// Full-precision storage: a plain matrix (the zero-cost default).
    F32(Mat),
    /// Half-precision storage: the narrowed bit patterns of the dtype.
    U16(Vec<u16>),
}

/// A matrix tagged with a storage dtype whose contents are always
/// representable in that dtype — and, for the half formats, physically
/// stored as 2-byte words.
#[derive(Clone, Debug, PartialEq)]
pub struct QMat {
    dtype: Dtype,
    rows: usize,
    cols: usize,
    payload: Payload,
}

impl QMat {
    /// Quantize `m` under `policy` (honouring its rounding mode) and store
    /// the result in the policy's storage dtype.
    pub fn store(policy: &Policy, m: &Mat) -> QMat {
        let q = policy.quantized(m);
        QMat::from_quantized(policy.store, q)
    }

    /// Narrow an already-representable matrix into `dtype` storage with
    /// nearest-even conversion (exact when `m` was produced by `widen` or
    /// `Policy::quantize_mat` under the same dtype).
    pub fn from_quantized(dtype: Dtype, m: Mat) -> QMat {
        let (rows, cols) = (m.rows(), m.cols());
        let payload = match dtype {
            Dtype::F32 => Payload::F32(m),
            Dtype::Bf16 => {
                Payload::U16(m.data().iter().map(|&x| Bf16::from_f32(x).bits()).collect())
            }
            Dtype::Fp16 => {
                Payload::U16(m.data().iter().map(|&x| Fp16::from_f32(x).bits()).collect())
            }
        };
        QMat { dtype, rows, cols, payload }
    }

    /// An all-zeros matrix in `dtype` storage.
    pub fn zeros(dtype: Dtype, rows: usize, cols: usize) -> QMat {
        match dtype {
            Dtype::F32 => {
                QMat { dtype, rows, cols, payload: Payload::F32(Mat::zeros(rows, cols)) }
            }
            _ => QMat { dtype, rows, cols, payload: Payload::U16(vec![0u16; rows * cols]) },
        }
    }

    /// The identity matrix in `dtype` storage (1.0 is exact in all formats).
    pub fn eye(dtype: Dtype, n: usize) -> QMat {
        QMat::from_quantized(dtype, Mat::eye(n))
    }

    /// Widen to a full-precision working copy (exact).
    pub fn widen(&self) -> Mat {
        match &self.payload {
            Payload::F32(m) => m.clone(),
            Payload::U16(bits) => {
                let w = widen_fn(self.dtype);
                Mat::from_vec(self.rows, self.cols, bits.iter().map(|&b| w(b)).collect())
            }
        }
    }

    /// Storage dtype tag.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    /// True iff the matrix has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Physical payload bytes (the real memory footprint — 2 bytes per
    /// element for half formats, 4 for f32).
    pub fn bytes(&self) -> usize {
        self.len() * self.dtype.bytes()
    }

    /// `self @ b`, widening `self` at pack time. Bitwise identical to
    /// `matmul(&self.widen(), b)` at every size.
    pub fn matmul_qa(&self, b: &Mat) -> Mat {
        match &self.payload {
            Payload::F32(m) => matmul(m, b),
            Payload::U16(bits) => {
                matmul_wa_b(bits, widen_fn(self.dtype), self.rows, self.cols, b)
            }
        }
    }

    /// `a @ self`, widening `self` at pack time. Bitwise identical to
    /// `matmul(a, &self.widen())` at every size.
    pub fn matmul_qb(&self, a: &Mat) -> Mat {
        match &self.payload {
            Payload::F32(m) => matmul(a, m),
            Payload::U16(bits) => {
                matmul_a_wb(a, bits, widen_fn(self.dtype), self.rows, self.cols)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Pcg;

    #[test]
    fn f32_store_is_the_identity() {
        let mut rng = Pcg::new(3);
        let m = rng.normal_mat(5, 7, 1.0);
        let q = QMat::store(&Policy::fp32(), &m);
        assert_eq!(q.dtype(), Dtype::F32);
        assert_eq!(q.widen(), m);
        assert_eq!(q.bytes(), 5 * 7 * 4);
    }

    #[test]
    fn half_store_widen_roundtrips_bitwise() {
        // store → widen → store must be a fixed point: widening is exact,
        // so the second narrowing reproduces the same u16 words.
        let mut rng = Pcg::new(11);
        let m = rng.normal_mat(9, 6, 2.0);
        for policy in [Policy::bf16_mixed(), Policy::fp16_mixed()] {
            let q = QMat::store(&policy, &m);
            assert_eq!(q.bytes(), 9 * 6 * 2, "half payloads are 2 bytes/elem");
            let w = q.widen();
            assert_eq!(w, policy.quantized(&m), "widen equals the quantized image");
            let q2 = QMat::store(&policy, &w);
            assert_eq!(q, q2, "store∘widen must be a fixed point");
        }
    }

    #[test]
    fn qmat_matmul_matches_widened_matmul_bitwise() {
        let mut rng = Pcg::new(29);
        // Small (tiny path) and large (packed/pooled path) shapes.
        for (m, k, n) in [(3usize, 4usize, 5usize), (70, 90, 80)] {
            let a = rng.normal_mat(m, k, 1.0);
            let b = rng.normal_mat(k, n, 1.0);
            for policy in [Policy::fp32(), Policy::bf16_mixed(), Policy::fp16_mixed()] {
                let qa = QMat::store(&policy, &a);
                let qb = QMat::store(&policy, &b);
                assert_eq!(qa.matmul_qa(&b), matmul(&qa.widen(), &b), "qa {m}x{k}x{n}");
                assert_eq!(qb.matmul_qb(&a), matmul(&a, &qb.widen()), "qb {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn zeros_and_eye_are_exact() {
        for dtype in [Dtype::F32, Dtype::Bf16, Dtype::Fp16] {
            assert_eq!(QMat::zeros(dtype, 3, 2).widen(), Mat::zeros(3, 2));
            assert_eq!(QMat::eye(dtype, 4).widen(), Mat::eye(4));
        }
    }
}
