//! Table 2 — iteration cost per structure.
//!
//! Measures, for each structure and a sweep of layer widths `d`, the two
//! second-order cost centres of Fig. 4:
//!
//! 1. preconditioner refresh (`Π̂(H)`, `Π̂(KᵀK)`, multiplicative K update);
//! 2. descent direction (`C Cᵀ ∇W K Kᵀ`);
//!
//! and fits the scaling exponent `t ∝ d^α` between successive sizes. The
//! paper's claim is the *shape*: dense costs `O(d³)`-ish per refresh and
//! `O(d²·d_o)` per direction, (block-)diag/rank-k/hierarchical drop to
//! `O(k·m·d)` / `O(k d_i d_o)`, Toeplitz to quasi-linear in storage.
//!
//! Run: `cargo bench --bench tab2_iteration_cost`

use singd::bench::{black_box, Harness};
use singd::optim::{Hyper, KronStats, Method, Optimizer};
use singd::proptest::Pcg;
use singd::structured::Structure;

fn main() {
    let mut h = Harness::new("tab2_iteration_cost");
    h.target_secs = 0.3;
    let sizes = [64usize, 128, 256];
    let m = 64; // batch rows
    let structures: Vec<(&str, Method)> = vec![
        ("kfac", Method::Kfac),
        ("dense (INGD)", Method::Singd { structure: Structure::Dense }),
        ("block k=32", Method::Singd { structure: Structure::BlockDiag { k: 32 } }),
        ("hier k=16", Method::Singd { structure: Structure::Hierarchical { k1: 8, k2: 8 } }),
        ("rankk k=1", Method::Singd { structure: Structure::RankKTril { k: 1 } }),
        ("toeplitz", Method::Singd { structure: Structure::TriuToeplitz }),
        ("diag", Method::Singd { structure: Structure::Diagonal }),
        ("adamw", Method::AdamW),
    ];

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, method) in &structures {
        let mut times = Vec::new();
        for &d in &sizes {
            let mut rng = Pcg::new(7);
            let shapes = [(d, d)];
            let hp = Hyper { t_update: 1, ..Hyper::default() };
            let mut opt = method.build(&shapes, &hp);
            let mut params = [rng.normal_mat(d, d, 0.1)];
            let grads = [rng.normal_mat(d, d, 0.1)];
            let stats =
                [KronStats { a: rng.normal_mat(m, d, 1.0), g: rng.normal_mat(m, d, 1.0) }];
            let mut t = 0usize;
            let st = h.bench(&format!("{name} d={d} (refresh+direction)"), || {
                opt.step(t, &mut params, &grads, &stats);
                t += 1;
                black_box(params[0].at(0, 0));
            });
            times.push(st.median_ns);
        }
        rows.push((name.to_string(), times));
    }

    println!("\nScaling exponents t ∝ d^α (per doubling):");
    println!("{:<18} {:>12} {:>12} {:>8}", "structure", "d=64→128", "d=128→256", "α(avg)");
    for (name, times) in &rows {
        let a1 = (times[1] / times[0]).log2();
        let a2 = (times[2] / times[1]).log2();
        println!("{:<18} {:>12.2} {:>12.2} {:>8.2}", name, a1, a2, (a1 + a2) / 2.0);
    }
    println!("\nExpected (Table 2): dense/kfac α≈2–3; block/hier/diag/rankk α≈1–2;");
    println!("every structured variant strictly cheaper than dense at the same d.");

    // Sanity checks on the shape of the result (who wins).
    let get = |n: &str| rows.iter().find(|(name, _)| name.starts_with(n)).unwrap().1[2];
    assert!(get("diag") < get("dense"), "diag must beat dense at d=256");
    assert!(get("rankk") < get("dense"), "rank-1 must beat dense at d=256");
    assert!(get("hier") < get("dense"), "hier must beat dense at d=256");
    h.finish();
}
