//! Fig. 6 — transformer models in BFP16 on CIFAR-100 and ImageWoof-10:
//! {AdamW, IKFAC, SINGD-Diag, SINGD-BlockDiag, SINGD-Hier, INGD}.
//!
//! Expected shape (paper): SINGD variants (and INGD) match or beat AdamW;
//! the hierarchical structure tracks the dense one and tends to beat the
//! plain (block-)diagonal ones; everything trains stably in bf16.
//!
//! Scale with `SINGD_BENCH_EPOCHS` (default 6).
//! Run: `cargo bench --bench fig6_transformers`

use singd::config::{Arch, JobConfig};
use singd::exp::{cosine_for, default_hyper, run_grid};
use singd::optim::Method;
use singd::structured::Structure;

fn main() {
    let epochs: usize =
        std::env::var("SINGD_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let methods: Vec<_> = [
        Method::AdamW,
        Method::Ikfac { structure: Structure::Dense },
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::BlockDiag { k: 8 } },
        Method::Singd { structure: Structure::Hierarchical { k1: 4, k2: 4 } },
        Method::Singd { structure: Structure::Dense }, // INGD
    ]
    .into_iter()
    .map(|m| {
        let hp = default_hyper(&m, true);
        (m, hp)
    })
    .collect();

    let mut all_csv = String::new();
    for (ds, classes, n_train) in [("cifar100", 20usize, 900usize), ("imagewoof", 10, 600)] {
        println!("\n== Fig. 6 — Compact-ViT-ish on {ds}, bf16, {epochs} epochs ==");
        let base = JobConfig {
            arch: Arch::Vit { dim: 24, depth: 2, patch: 4 },
            dataset: ds.into(),
            classes,
            n_train,
            n_test: 240,
            method: Method::AdamW,
            hyper: default_hyper(&Method::AdamW, true),
            schedule: cosine_for(epochs, n_train, 32),
            epochs,
            batch_size: 32,
            seed: 23,
            label: format!("fig6-{ds}"),
            ranks: 1,
            dist_strategy: singd::dist::DistStrategy::Replicated,
            transport: singd::dist::Transport::Local,
            algo: singd::dist::default_algo(),
            overlap: singd::dist::default_overlap(),
            wire_dtype: singd::dist::default_wire_dtype(),
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            elastic: false,
            trace_dir: None,
            log: None,
        };
        let grid = run_grid(&base, &methods, &["bf16"]);
        for (label, res) in &grid {
            all_csv.push_str(&res.to_csv(&format!("{ds}/{label}")));
        }
        let err = |l: &str| {
            grid.iter().find(|(n, _)| n == l).map(|(_, r)| r.best_test_err).unwrap()
        };
        let best_singd = ["singd:diag-bf16", "singd:block:8-bf16", "singd:hier:8-bf16", "ingd-bf16"]
            .iter()
            .map(|l| err(l))
            .fold(f32::INFINITY, f32::min);
        println!("\n{ds}: best SINGD {:.3} vs AdamW {:.3}", best_singd, err("adamw-bf16"));
        assert!(grid.iter().all(|(_, r)| !r.diverged), "all methods stable in bf16");
        assert!(
            best_singd <= err("adamw-bf16") + 0.05,
            "{ds}: SINGD family should match/beat AdamW (paper Fig. 6)"
        );
        // Hierarchical tracks dense (paper: 'often performs as well').
        assert!(
            err("singd:hier:8-bf16") <= err("ingd-bf16") + 0.12,
            "{ds}: hierarchical should track dense"
        );
    }
    singd::train::write_csv("fig6_transformer_curves.csv", &all_csv).ok();
}
