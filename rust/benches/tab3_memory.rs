//! Table 3 (additional storage) + Fig. 1 right (memory bars).
//!
//! Reports the optimizer-state bytes of every method over the layer-shape
//! profiles of the experiment models (VGG and ViT), in fp32 and bf16, and
//! checks the paper's ordering: SINGD-structured < AdamW < INGD ≈ KFAC,
//! with SINGD-Diag in bf16 at or below AdamW-bf16 (Fig. 1 right's dashed
//! line).
//!
//! Run: `cargo bench --bench tab3_memory`

use singd::bench::Harness;
use singd::config::Arch;
use singd::exp::{build_model, default_hyper};
use singd::model::cnn::ImgShape;
use singd::numerics::Policy;
use singd::optim::Method;
use singd::proptest::Pcg;
use singd::structured::Structure;

fn main() {
    let mut h = Harness::new("tab3_memory");
    let shape = ImgShape { c: 3, h: 16, w: 16 };
    let mut rng = Pcg::new(1);

    let profiles = [
        ("vgg(w=16)", Arch::Vgg { width: 16 }),
        ("vit(d=64,L=4)", Arch::Vit { dim: 64, depth: 4, patch: 4 }),
    ];
    let methods = [
        Method::Kfac,
        Method::Singd { structure: Structure::Dense },
        Method::Ikfac { structure: Structure::Dense },
        Method::Singd { structure: Structure::BlockDiag { k: 32 } },
        Method::Singd { structure: Structure::Hierarchical { k1: 8, k2: 8 } },
        Method::Singd { structure: Structure::RankKTril { k: 1 } },
        Method::Singd { structure: Structure::TriuToeplitz },
        Method::Singd { structure: Structure::Diagonal },
        Method::AdamW,
        Method::Sgd,
    ];

    for (pname, arch) in &profiles {
        let cfg = singd::config::JobConfig {
            arch: arch.clone(),
            dataset: "cifar100".into(),
            classes: 100,
            n_train: 1,
            n_test: 1,
            method: Method::Sgd,
            hyper: default_hyper(&Method::Sgd, false),
            schedule: singd::train::Schedule::Constant,
            epochs: 1,
            batch_size: 1,
            seed: 0,
            label: "mem".into(),
            ranks: 1,
            dist_strategy: singd::dist::DistStrategy::Replicated,
            transport: singd::dist::Transport::Local,
            algo: singd::dist::default_algo(),
            overlap: singd::dist::default_overlap(),
            wire_dtype: singd::dist::default_wire_dtype(),
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            elastic: false,
            trace_dir: None,
            log: None,
        };
        let model = build_model(&cfg, shape, 100, &mut rng);
        let shapes = model.shapes();
        let n_params: usize = shapes.iter().map(|&(o, i)| o * i).sum();
        println!("\n-- {pname}: {} layers, {} params --", shapes.len(), n_params);
        println!("{:<22} {:>14} {:>14}", "method", "fp32 bytes", "bf16 bytes");
        let mut table = Vec::new();
        for method in &methods {
            let mut hp32 = default_hyper(method, false);
            hp32.policy = Policy::fp32();
            let mut hp16 = hp32.clone();
            hp16.policy = Policy::bf16_mixed();
            let b32 = method.build(&shapes, &hp32).state_bytes();
            let b16 = method.build(&shapes, &hp16).state_bytes();
            println!("{:<22} {:>14} {:>14}", method.name(), b32, b16);
            h.record(&format!("{pname}/{}/fp32", method.name()), b32 as f64, "bytes");
            h.record(&format!("{pname}/{}/bf16", method.name()), b16 as f64, "bytes");
            table.push((method.name(), b32, b16));
        }
        let get = |n: &str| table.iter().find(|(name, _, _)| name == n).unwrap().1;
        // Paper orderings (Table 3 / Fig. 1R).
        assert!(get("singd:diag") < get("adamw"), "{pname}: diag < adamw");
        assert!(get("singd:toeplitz") < get("adamw"), "{pname}: toeplitz < adamw");
        assert!(get("adamw") < get("ingd"), "{pname}: adamw < ingd(dense)");
        assert!(get("ikfac") < get("ingd"), "{pname}: ikfac (no Riemannian momentum) < ingd");
        assert!(get("singd:block:32") < get("ingd"), "{pname}: block < dense");
        // Fig. 1R dashed line: SINGD-Diag bf16 ≤ AdamW bf16.
        let diag16 = table.iter().find(|(n, _, _)| n == "singd:diag").unwrap().2;
        let adamw16 = table.iter().find(|(n, _, _)| n == "adamw").unwrap().2;
        assert!(diag16 <= adamw16, "{pname}: diag-bf16 ≤ adamw-bf16");
    }
    println!("\nAll Table-3 orderings hold: structured SINGD ≤ AdamW < INGD/KFAC.");
    h.finish();
}
