//! Distributed-scaling bench: step time and per-rank Kronecker-factor
//! memory vs. world size, for both dist strategies.
//!
//! Same JSON shape as `BENCH_hotpath.json` (a `cases` array of timing
//! stats), with per-case `ranks` / `strategy` / `per_rank_state_bytes`
//! fields. The memory column is the paper's Table-3 story stretched
//! across ranks: under `factor-sharded`, per-rank factor bytes drop
//! ~1/R while the replicated strategy pays the full footprint on every
//! rank.
//!
//! Run: `cargo bench --bench dist_scaling`
//! CI:  `cargo bench --bench dist_scaling -- --smoke`

use singd::bench::{Harness, Stats};
use singd::data;
use singd::dist::{DistCtx, DistStrategy};
use singd::model::cnn::ImgShape;
use singd::model::Mlp;
use singd::optim::{Hyper, Method, Optimizer};
use singd::proptest::Pcg;
use singd::tensor::pool;
use singd::train::{train_dist, DistCfg, TrainCfg};

struct Row {
    stats: Stats,
    ranks: usize,
    strategy: &'static str,
    per_rank_state_bytes: usize,
    steps: usize,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(rows: &[Row], smoke: bool) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"dist_scaling\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str(&format!("  \"threads\": {},\n", pool::num_threads()));
    out.push_str("  \"cases\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let s = &row.stats;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \"min_ns\": {:.1}, \"max_ns\": {:.1}, \"ranks\": {}, \"strategy\": \"{}\", \"steps\": {}, \"median_step_ns\": {:.1}, \"per_rank_state_bytes\": {}}}",
            json_escape(&s.name),
            s.iters,
            s.median_ns,
            s.mean_ns,
            s.min_ns,
            s.max_ns,
            row.ranks,
            row.strategy,
            row.steps,
            s.median_ns / row.steps.max(1) as f64,
            row.per_rank_state_bytes,
        ));
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_dist_scaling.json", &out) {
        Ok(()) => println!("-- wrote BENCH_dist_scaling.json"),
        Err(e) => eprintln!("-- failed to write BENCH_dist_scaling.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut h = Harness::new("dist_scaling");
    if smoke {
        h.target_secs = 0.0;
        h.max_iters = 1;
    } else {
        h.target_secs = 1.0;
        h.max_iters = 20;
    }
    let mut rows: Vec<Row> = Vec::new();

    // A meaty INGD workload: eight near-equal dense-factor layers (so
    // round-robin sharding splits state evenly and the 1/R memory story
    // is visible) over an 8-batch epoch, preconditioner refreshed every
    // step.
    let mut rng = Pcg::new(5);
    let ds = data::prototype_images(&mut rng, ImgShape { c: 1, h: 8, w: 8 }, 8, 256, 64, 2.0);
    let dims = [64, 64, 64, 64, 64, 64, 64, 64, 8];
    let method = Method::Singd { structure: singd::structured::Structure::Dense };
    let cfg = TrainCfg {
        method: method.clone(),
        hyper: Hyper { lr: 0.02, t_update: 1, ..Hyper::default() },
        epochs: 1,
        batch_size: 32,
        seed: 11,
        ..TrainCfg::default()
    };
    let steps = cfg.epochs * (256 / cfg.batch_size);

    for &ranks in &[1usize, 2, 4] {
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            if ranks == 1 && strategy == DistStrategy::FactorSharded {
                continue; // degenerate: identical to replicated
            }
            let shapes: Vec<(usize, usize)> =
                dims.windows(2).map(|w| (w[1], w[0] + 1)).collect();
            let per_rank_state_bytes = method
                .build_dist(&shapes, &cfg.hyper, DistCtx::new(strategy, 0, ranks))
                .state_bytes();
            let dc = DistCfg::local(ranks, strategy);
            let name = format!("train step ranks={ranks} {}", strategy.name());
            let st = h.bench(&name, || {
                let mut mrng = Pcg::new(7);
                let mut model = Mlp::new(&mut mrng, &dims);
                let res = train_dist(&mut model, &ds, &cfg, &dc);
                assert!(!res.diverged, "bench run diverged");
            });
            println!(
                "{:>46} {:.2} ms/step, {} per-rank state bytes",
                "->",
                st.median_ns / steps as f64 / 1e6,
                per_rank_state_bytes
            );
            rows.push(Row {
                stats: st,
                ranks,
                strategy: strategy.name(),
                per_rank_state_bytes,
                steps,
            });
        }
    }

    // The headline memory claim in one line: sharded rank-0 bytes vs
    // replicated, at the largest world size.
    let rep = rows.iter().find(|r| r.ranks == 4 && r.strategy == "replicated").unwrap();
    let sh = rows.iter().find(|r| r.ranks == 4 && r.strategy == "factor-sharded").unwrap();
    println!(
        "-- ranks=4 per-rank factor state: replicated {} B, factor-sharded {} B ({:.2}x)",
        rep.per_rank_state_bytes,
        sh.per_rank_state_bytes,
        rep.per_rank_state_bytes as f64 / sh.per_rank_state_bytes.max(1) as f64
    );

    if smoke {
        println!("-- smoke mode: skipping BENCH_dist_scaling.json");
    } else {
        write_json(&rows, smoke);
    }
    h.finish();
}
