//! Distributed-scaling bench: step time, per-rank Kronecker-factor
//! memory, and per-rank bytes-on-wire vs. world size — for both dist
//! strategies, both collective algorithms (star vs ring), both overlap
//! modes (blocking vs nonblocking/chunk-pipelined) and both streaming
//! modes (gathers issued after the backward vs from inside it).
//!
//! Same JSON shape as `BENCH_hotpath.json` (a `cases` array of timing
//! stats) with per-case `ranks` / `strategy` / `algo` / `overlap` /
//! `stream` / `per_rank_state_bytes` / `wire_bytes_by_rank` fields, plus a
//! `collectives` array that isolates the bandwidth story: one all-reduce
//! of a fixed payload, measured through `singd::dist::traffic`. The
//! memory column is the paper's Table-3 story stretched across ranks;
//! the wire column is the ISSUE-4 story — the star's rank-0 fan-in sends
//! `~(R−1)·R·N` bytes from rank 0 while the ring sends a balanced
//! `~2·(R−1)/R·N` from every rank. The overlap axis is the ISSUE-5
//! story: ring rows appear as a blocking-vs-pipelined series (overlap 0
//! vs 1 — same bits, the knob only moves wall-clock), and the isolated
//! `all_reduce` timing rows compare the blocking ring against the
//! chunk-pipelined ring on a multi-stage payload at world 4. The stream
//! axis is the ISSUE-9 story: with streaming on, each layer's stats
//! gather is issued from inside its backward hook, so the traced-epoch
//! rows show a strictly larger hidden-comm fraction at ranks=4 ring
//! (same bits — contract 8 — and same bytes; only issue time moves).
//!
//! Run: `cargo bench --bench dist_scaling`
//! CI:  `cargo bench --bench dist_scaling -- --smoke`

use singd::bench::{Harness, Stats};
use singd::data;
use singd::dist::{self, collectives, traffic, Algo, DistCtx, DistStrategy};
use singd::model::cnn::ImgShape;
use singd::model::Mlp;
use singd::numerics::Dtype;
use singd::obs::trace::{self, RankOverlap};
use singd::optim::{Hyper, Method, Optimizer};
use singd::proptest::Pcg;
use singd::tensor::{pool, Mat};
use singd::train::{train_dist, DistCfg, TrainCfg};

struct Row {
    stats: Stats,
    ranks: usize,
    strategy: &'static str,
    algo: &'static str,
    overlap: bool,
    /// Whether per-layer stats gathers were issued from inside the
    /// backward hooks (ISSUE 9; bitwise-inert by contract 8, so the
    /// byte columns match the unstreamed row — only wall-clock moves).
    stream: bool,
    wire: &'static str,
    per_rank_state_bytes: usize,
    wire_bytes_by_rank: Vec<u64>,
    steps: usize,
}

struct CollectiveRow {
    algo: &'static str,
    /// Whether the overlapped (chunk-pipelined, for the ring) schedule
    /// produced these bytes.
    overlap: bool,
    /// Wire dtype the bulk payload travelled as (ISSUE 8: half wire
    /// dtypes halve the per-rank payload bytes).
    wire: &'static str,
    world: usize,
    payload_bytes: usize,
    sent_by_rank: Vec<u64>,
}

/// Trace-derived comm/compute overlap efficiency of one traced epoch:
/// how much of each rank's comm-span time was hidden under compute
/// (ISSUE-7 story — the fraction the overlap knob actually buys, as
/// measured from the span tracer rather than modeled).
struct OverlapEffRow {
    overlap: bool,
    stream: bool,
    by_rank: Vec<RankOverlap>,
}

impl OverlapEffRow {
    fn mean_hidden_frac(&self) -> f64 {
        let comm: u64 = self.by_rank.iter().map(|r| r.comm_us).sum();
        let hidden: u64 = self.by_rank.iter().map(|r| r.hidden_us).sum();
        if comm == 0 {
            0.0
        } else {
            hidden as f64 / comm as f64
        }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_u64_array(xs: &[u64]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn json_f64_array(xs: &[f64]) -> String {
    let items: Vec<String> = xs.iter().map(|x| format!("{x:.4}")).collect();
    format!("[{}]", items.join(", "))
}

fn write_json(rows: &[Row], colls: &[CollectiveRow], effs: &[OverlapEffRow], smoke: bool) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"dist_scaling\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str(&format!("  \"threads\": {},\n", pool::num_threads()));
    out.push_str("  \"cases\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let s = &row.stats;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \"min_ns\": {:.1}, \"max_ns\": {:.1}, \"ranks\": {}, \"strategy\": \"{}\", \"algo\": \"{}\", \"overlap\": {}, \"stream\": {}, \"wire\": \"{}\", \"steps\": {}, \"median_step_ns\": {:.1}, \"per_rank_state_bytes\": {}, \"wire_bytes_by_rank\": {}, \"max_rank_wire_bytes\": {}}}",
            json_escape(&s.name),
            s.iters,
            s.median_ns,
            s.mean_ns,
            s.min_ns,
            s.max_ns,
            row.ranks,
            row.strategy,
            row.algo,
            row.overlap,
            row.stream,
            row.wire,
            row.steps,
            s.median_ns / row.steps.max(1) as f64,
            row.per_rank_state_bytes,
            json_u64_array(&row.wire_bytes_by_rank),
            row.wire_bytes_by_rank.iter().max().copied().unwrap_or(0),
        ));
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"collectives\": [\n");
    for (i, c) in colls.iter().enumerate() {
        let max = c.sent_by_rank.iter().max().copied().unwrap_or(0);
        let ring_optimal =
            2 * (c.world as u64 - 1) * c.payload_bytes as u64 / c.world as u64;
        out.push_str(&format!(
            "    {{\"op\": \"all_reduce\", \"algo\": \"{}\", \"overlap\": {}, \"wire\": \"{}\", \"world\": {}, \"payload_bytes\": {}, \"sent_by_rank\": {}, \"max_rank_sent_bytes\": {}, \"ring_optimal_per_rank_bytes\": {}}}",
            c.algo,
            c.overlap,
            c.wire,
            c.world,
            c.payload_bytes,
            json_u64_array(&c.sent_by_rank),
            max,
            ring_optimal,
        ));
        out.push_str(if i + 1 < colls.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    // Overlap efficiency: measured from the span tracer (trace::begin
    // with no export dir around one epoch, then trace::overlap_stats),
    // not modeled — the hidden-comm fraction ring overlap buys.
    out.push_str("  \"overlap_efficiency\": [\n");
    for (i, e) in effs.iter().enumerate() {
        let comm: Vec<u64> = e.by_rank.iter().map(|r| r.comm_us).collect();
        let hidden: Vec<u64> = e.by_rank.iter().map(|r| r.hidden_us).collect();
        let fracs: Vec<f64> = e.by_rank.iter().map(|r| r.hidden_frac()).collect();
        out.push_str(&format!(
            "    {{\"name\": \"traced epoch ranks=4 factor-sharded ring\", \"overlap\": {}, \"stream\": {}, \"comm_us_by_rank\": {}, \"hidden_us_by_rank\": {}, \"hidden_frac_by_rank\": {}, \"mean_hidden_frac\": {:.4}}}",
            e.overlap,
            e.stream,
            json_u64_array(&comm),
            json_u64_array(&hidden),
            json_f64_array(&fracs),
            e.mean_hidden_frac(),
        ));
        out.push_str(if i + 1 < effs.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_dist_scaling.json", &out) {
        Ok(()) => println!("-- wrote BENCH_dist_scaling.json"),
        Err(e) => eprintln!("-- failed to write BENCH_dist_scaling.json: {e}"),
    }
}

/// Per-rank payload-frame bytes of one `all_reduce_sum` of `payload`
/// under `algo` at `world` ranks with the given overlap mode
/// (in-process transport; the byte model is the socket frame layout
/// either way — under overlap the ring runs chunk-pipelined, paying one
/// extra frame header per additional pipeline stage round).
fn measure_collective(
    world: usize,
    algo: Algo,
    overlap: bool,
    wire: Dtype,
    payload: &Mat,
) -> CollectiveRow {
    traffic::reset();
    let outs = dist::run_ranks_wire(world, algo, overlap, wire, |c| {
        let red = collectives::all_reduce_sum(&c, std::slice::from_ref(payload));
        red[0].at(0, 0)
    });
    assert!(outs.iter().all(|&x| x == outs[0]));
    CollectiveRow {
        algo: algo.name(),
        overlap,
        wire: wire.name(),
        world,
        // Dtype-sized: the logical payload as it travels the wire, so the
        // ring-optimal model below stays exact for half wire dtypes too.
        payload_bytes: wire.bytes() * payload.len(),
        sent_by_rank: traffic::sent_by_rank(world),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut h = Harness::new("dist_scaling");
    if smoke {
        h.target_secs = 0.0;
        h.max_iters = 1;
    } else {
        h.target_secs = 1.0;
        h.max_iters = 20;
    }
    let mut rows: Vec<Row> = Vec::new();

    // A meaty INGD workload: eight near-equal dense-factor layers (so
    // round-robin sharding splits state evenly and the 1/R memory story
    // is visible) over an 8-batch epoch, preconditioner refreshed every
    // step.
    let mut rng = Pcg::new(5);
    let ds = data::prototype_images(&mut rng, ImgShape { c: 1, h: 8, w: 8 }, 8, 256, 64, 2.0);
    let dims = [64, 64, 64, 64, 64, 64, 64, 64, 8];
    let method = Method::Singd { structure: singd::structured::Structure::Dense };
    let cfg = TrainCfg {
        method: method.clone(),
        hyper: Hyper { lr: 0.02, t_update: 1, ..Hyper::default() },
        epochs: 1,
        batch_size: 32,
        seed: 11,
        ..TrainCfg::default()
    };
    let steps = cfg.epochs * (256 / cfg.batch_size);

    for &ranks in &[1usize, 2, 4] {
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            if ranks == 1 && strategy == DistStrategy::FactorSharded {
                continue; // degenerate: identical to replicated
            }
            for algo in [Algo::Star, Algo::Ring] {
                if ranks == 1 && algo == Algo::Star {
                    continue; // no collectives at world 1: one baseline row
                }
                // The blocking-vs-pipelined-vs-streamed series: ring
                // rows at every multi-rank world run blocking, then
                // pipelined with post-backward gather issue, then
                // pipelined with in-backward (streamed) issue — same
                // bits by contracts 4 and 8; both axes only move
                // wall-clock. Star and the world-1 baseline are pinned
                // to the defaults (stream needs overlap, so it is inert
                // on blocking rows and omitted there).
                let modes: &[(bool, bool)] = if algo == Algo::Ring && ranks > 1 {
                    &[(false, false), (true, false), (true, true)]
                } else {
                    &[(true, true)]
                };
                for &(overlap, stream) in modes {
                    let shapes: Vec<(usize, usize)> =
                        dims.windows(2).map(|w| (w[1], w[0] + 1)).collect();
                    let per_rank_state_bytes = method
                        .build_dist(&shapes, &cfg.hyper, DistCtx::new(strategy, 0, ranks))
                        .state_bytes();
                    let mut dc = DistCfg::local(ranks, strategy);
                    dc.algo = algo;
                    dc.overlap = overlap;
                    dc.stream = stream;
                    // One traffic-accounted run before timing: per-rank
                    // payload-frame bytes for the whole 8-step epoch.
                    traffic::reset();
                    {
                        let mut mrng = Pcg::new(7);
                        let mut model = Mlp::new(&mut mrng, &dims);
                        let res = train_dist(&mut model, &ds, &cfg, &dc);
                        assert!(!res.diverged, "bench run diverged");
                    }
                    let wire_bytes_by_rank = traffic::sent_by_rank(ranks);
                    let name = format!(
                        "train step ranks={ranks} {} {} overlap={} stream={}",
                        strategy.name(),
                        algo.name(),
                        overlap as u8,
                        stream as u8
                    );
                    let st = h.bench(&name, || {
                        let mut mrng = Pcg::new(7);
                        let mut model = Mlp::new(&mut mrng, &dims);
                        let res = train_dist(&mut model, &ds, &cfg, &dc);
                        assert!(!res.diverged, "bench run diverged");
                    });
                    println!(
                        "{:>46} {:.2} ms/step, {} per-rank state bytes, wire max {} B/rank",
                        "->",
                        st.median_ns / steps as f64 / 1e6,
                        per_rank_state_bytes,
                        wire_bytes_by_rank.iter().max().copied().unwrap_or(0),
                    );
                    rows.push(Row {
                        stats: st,
                        ranks,
                        strategy: strategy.name(),
                        algo: algo.name(),
                        overlap,
                        stream,
                        wire: dc.wire_dtype.name(),
                        per_rank_state_bytes,
                        wire_bytes_by_rank,
                        steps,
                    });
                }
            }
        }
    }

    // The wire-compression story (ISSUE 8): the same ranks=4 ring epoch
    // with bf16 collective payloads. Bulk frames carry 2-byte elements,
    // so per-rank wire bytes drop ~2× (frame headers and the exact f64
    // control plane are unaffected); the bits stay invariant across
    // algo × overlap at the fixed bf16 wire (contract 7), which
    // rust/tests/dist.rs `wire_` cells pin.
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let shapes: Vec<(usize, usize)> = dims.windows(2).map(|w| (w[1], w[0] + 1)).collect();
        let per_rank_state_bytes = method
            .build_dist(&shapes, &cfg.hyper, DistCtx::new(strategy, 0, 4))
            .state_bytes();
        let mut dc = DistCfg::local(4, strategy);
        dc.algo = Algo::Ring;
        dc.overlap = true;
        dc.stream = true;
        dc.wire_dtype = Dtype::Bf16;
        traffic::reset();
        {
            let mut mrng = Pcg::new(7);
            let mut model = Mlp::new(&mut mrng, &dims);
            let res = train_dist(&mut model, &ds, &cfg, &dc);
            assert!(!res.diverged, "bf16-wire bench run diverged");
        }
        let wire_bytes_by_rank = traffic::sent_by_rank(4);
        if let Some(f32_row) = rows.iter().find(|r| {
            r.ranks == 4
                && r.strategy == strategy.name()
                && r.algo == "ring"
                && r.overlap
                && r.stream
                && r.wire == dist::default_wire_dtype().name()
        }) {
            let f32_max = f32_row.wire_bytes_by_rank.iter().max().copied().unwrap_or(0);
            let bf16_max = wire_bytes_by_rank.iter().max().copied().unwrap_or(0);
            println!(
                "-- ranks=4 {} ring bf16 wire: max {} B/rank vs {} B/rank f32 ({:.2}x reduction)",
                strategy.name(),
                bf16_max,
                f32_max,
                f32_max as f64 / bf16_max.max(1) as f64,
            );
        }
        let name = format!(
            "train step ranks=4 {} ring overlap=1 stream=1 wire=bf16",
            strategy.name()
        );
        let st = h.bench(&name, || {
            let mut mrng = Pcg::new(7);
            let mut model = Mlp::new(&mut mrng, &dims);
            let res = train_dist(&mut model, &ds, &cfg, &dc);
            assert!(!res.diverged, "bf16-wire bench run diverged");
        });
        rows.push(Row {
            stats: st,
            ranks: 4,
            strategy: strategy.name(),
            algo: "ring",
            overlap: true,
            stream: true,
            wire: "bf16",
            per_rank_state_bytes,
            wire_bytes_by_rank,
            steps,
        });
    }

    // The bandwidth story isolated: one 1-MiB all-reduce at world 4.
    // Star: rank 0 sends (R−1)·(gathered blob ≈ R·N); ring: every rank
    // sends 2·(R−1)/R·N; the pipelined ring moves the same payload with
    // one extra header per additional stage round; the bf16 wire halves
    // the per-element width on either algo.
    let payload = Mat::from_fn(512, 512, |r, c| (r * 31 + c) as f32 * 1e-3);
    let colls: Vec<CollectiveRow> = [
        (Algo::Star, false, Dtype::F32),
        (Algo::Ring, false, Dtype::F32),
        (Algo::Ring, true, Dtype::F32),
        (Algo::Star, false, Dtype::Bf16),
        (Algo::Ring, false, Dtype::Bf16),
    ]
    .iter()
    .map(|&(algo, overlap, wire)| {
        let c = measure_collective(4, algo, overlap, wire, &payload);
        println!(
            "-- all_reduce 1 MiB world=4 {} overlap={} wire={}: sent/rank {:?} (max {} B)",
            c.algo,
            c.overlap as u8,
            c.wire,
            c.sent_by_rank,
            c.sent_by_rank.iter().max().copied().unwrap_or(0),
        );
        c
    })
    .collect();

    // The blocking-vs-pipelined wall-clock story isolated: the same
    // 1-MiB (8-stage under the auto plan) ring all-reduce, timed.
    for overlap in [false, true] {
        let pl = &payload;
        let st = h.bench(
            &format!("all_reduce 1MiB world=4 ring overlap={}", overlap as u8),
            || {
                let outs = dist::run_ranks_with(4, Algo::Ring, overlap, |c| {
                    collectives::all_reduce_sum(&c, std::slice::from_ref(pl))[0].at(0, 0)
                });
                std::hint::black_box(outs);
            },
        );
        rows.push(Row {
            stats: st,
            ranks: 4,
            strategy: "collective",
            algo: "ring",
            overlap,
            // No backward pass in an isolated collective — stream moot.
            stream: false,
            wire: dist::default_wire_dtype().name(),
            per_rank_state_bytes: 0,
            wire_bytes_by_rank: Vec::new(),
            steps: 1,
        });
    }

    // Overlap efficiency from the tracer: one traced epoch per
    // (overlap, stream) mode (ring, factor-sharded, world 4) under an
    // in-memory session (`trace::begin(None, ..)` — spans only, no
    // artifacts), reduced by `trace::overlap_stats` to the per-rank
    // hidden-comm fraction. This is the measured counterpart of the
    // blocking-vs-pipelined timing rows above: the knob's win is
    // compute hiding comm, and the tracer sees exactly which comm-span
    // microseconds compute covered. The streamed row is the ISSUE-9
    // headline — issuing each layer's gather from inside its backward
    // hook exposes the rest of the backward as hiding time, so its
    // hidden-comm fraction must come out strictly above the
    // post-backward-issue row's.
    let effs: Vec<OverlapEffRow> = [(false, false), (true, false), (true, true)]
        .iter()
        .map(|&(overlap, stream)| {
            let mut dc = DistCfg::local(4, DistStrategy::FactorSharded);
            dc.algo = Algo::Ring;
            dc.overlap = overlap;
            dc.stream = stream;
            assert!(trace::begin(None, 0), "a trace session is already armed");
            {
                let mut mrng = Pcg::new(7);
                let mut model = Mlp::new(&mut mrng, &dims);
                let res = train_dist(&mut model, &ds, &cfg, &dc);
                assert!(!res.diverged, "traced bench run diverged");
            }
            let row = OverlapEffRow {
                overlap,
                stream,
                by_rank: trace::overlap_stats(&trace::finish()),
            };
            println!(
                "-- traced epoch ranks=4 ring overlap={} stream={}: mean hidden-comm frac {:.1}%",
                overlap as u8,
                stream as u8,
                100.0 * row.mean_hidden_frac(),
            );
            row
        })
        .collect();
    if let (Some(off), Some(on)) = (
        effs.iter().find(|e| e.overlap && !e.stream),
        effs.iter().find(|e| e.overlap && e.stream),
    ) {
        println!(
            "-- stream-on hides {:.1}% of comm vs {:.1}% stream-off (ranks=4 ring overlap=1)",
            100.0 * on.mean_hidden_frac(),
            100.0 * off.mean_hidden_frac(),
        );
    }

    // The headline memory claim in one line: sharded rank-0 bytes vs
    // replicated, at the largest world size.
    let default_wire = dist::default_wire_dtype().name();
    let rep = rows
        .iter()
        .find(|r| {
            r.ranks == 4
                && r.strategy == "replicated"
                && r.algo == "ring"
                && r.overlap
                && r.stream
                && r.wire == default_wire
        })
        .unwrap();
    let sh = rows
        .iter()
        .find(|r| {
            r.ranks == 4
                && r.strategy == "factor-sharded"
                && r.algo == "ring"
                && r.overlap
                && r.stream
                && r.wire == default_wire
        })
        .unwrap();
    println!(
        "-- ranks=4 per-rank factor state: replicated {} B, factor-sharded {} B ({:.2}x)",
        rep.per_rank_state_bytes,
        sh.per_rank_state_bytes,
        rep.per_rank_state_bytes as f64 / sh.per_rank_state_bytes.max(1) as f64
    );

    if smoke {
        println!("-- smoke mode: skipping BENCH_dist_scaling.json");
    } else {
        write_json(&rows, &colls, &effs, smoke);
    }
    h.finish();
}
