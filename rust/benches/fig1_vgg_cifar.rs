//! Fig. 1 (left/center) — VGG on CIFAR-100: test-error curves for
//! {SGD, AdamW, KFAC, IKFAC, SINGD-Diag, INGD} in fp32 *and* bf16.
//!
//! Expected shape (paper): in fp32 all second-order methods beat AdamW and
//! IKFAC tracks KFAC; in bf16 KFAC destabilizes (Cholesky failures /
//! divergence) while the inverse-free methods keep training; SINGD-Diag
//! stays close to INGD at a fraction of the memory.
//!
//! Scale with `SINGD_BENCH_EPOCHS` (default 8).
//! Run: `cargo bench --bench fig1_vgg_cifar`

use singd::config::{Arch, JobConfig};
use singd::exp::{cosine_for, default_hyper, run_grid};
use singd::optim::Method;
use singd::structured::Structure;

fn main() {
    let epochs: usize =
        std::env::var("SINGD_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let base = JobConfig {
        arch: Arch::Vgg { width: 8 },
        dataset: "cifar100".into(),
        classes: 20,
        n_train: 1200,
        n_test: 300,
        method: Method::Sgd,
        hyper: default_hyper(&Method::Sgd, false),
        schedule: cosine_for(epochs, 1200, 32),
        epochs,
        batch_size: 32,
        seed: 17,
        label: "fig1".into(),
        ranks: 1,
        dist_strategy: singd::dist::DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
        algo: singd::dist::default_algo(),
        overlap: singd::dist::default_overlap(),
        wire_dtype: singd::dist::default_wire_dtype(),
        resume: None,
        ckpt: None,
        ckpt_every: 0,
        elastic: false,
        trace_dir: None,
        log: None,
    };
    // Theorem 1 is a statement about *matched* hyper-parameters: KFAC and
    // IKFAC get identical λ and β₁ so their preconditioners track. λ is
    // chosen low (2e-3) to stress the inversion the way large-scale
    // training does.
    let mk = |m: Method| {
        let mut hp = default_hyper(&m, true);
        if matches!(m, Method::Kfac | Method::Ikfac { .. }) {
            hp.damping = 2e-3;
            hp.precond_lr = 0.1;
        }
        (m, hp)
    };
    let methods = vec![
        mk(Method::Sgd),
        mk(Method::AdamW),
        mk(Method::Kfac),
        mk(Method::Ikfac { structure: Structure::Dense }),
        mk(Method::Singd { structure: Structure::Diagonal }),
        mk(Method::Singd { structure: Structure::Dense }), // INGD
    ];
    println!("Fig. 1 L/C — VGG(w=8) on synth-CIFAR-100(20), {epochs} epochs\n");
    // Precision columns: fp32, mixed bf16 (fp32 compute, bf16 storage — the
    // paper's BFP16 setting where KFAC *degrades* and hits Cholesky
    // failures it must paper over with a general inverse), and pure bf16
    // (every op rounded — what "run KFAC natively in 16 bit" would mean;
    // there is no 16-bit inverse kernel in real frameworks, which is the
    // paper's point — here the inversion itself breaks).
    let grid = run_grid(&base, &methods, &["fp32", "bf16", "bf16-pure"]);

    // Persist all curves.
    let mut csv = String::new();
    for (label, res) in &grid {
        csv.push_str(&res.to_csv(label));
    }
    singd::train::write_csv("fig1_vgg_cifar_curves.csv", &csv).ok();

    // Shape checks (who wins / who breaks).
    let get = |l: &str| grid.iter().find(|(name, _)| name == l).map(|(_, r)| r).unwrap();
    let err = |l: &str| get(l).best_test_err;
    println!("\n-- Fig. 1 shape summary --");
    println!("IKFAC-fp32 tracks KFAC-fp32:   {:.3} vs {:.3}", err("ikfac-fp32"), err("kfac-fp32"));
    println!("SINGD-Diag-bf16 ≈ INGD-bf16:   {:.3} vs {:.3}", err("singd:diag-bf16"), err("ingd-bf16"));
    println!(
        "KFAC under bf16: mixed err {:.3} ({}), pure err {:.3} ({}{})",
        err("kfac-bf16"),
        if get("kfac-bf16").diverged { "DIVERGED" } else { &get("kfac-bf16").telemetry },
        err("kfac-bf16-pure"),
        if get("kfac-bf16-pure").diverged { "DIVERGED " } else { "" },
        get("kfac-bf16-pure").telemetry,
    );
    println!(
        "inverse-free under pure bf16: ikfac={:.3} singd:diag={:.3} ingd={:.3} (all finite: {})",
        err("ikfac-bf16-pure"),
        err("singd:diag-bf16-pure"),
        err("ingd-bf16-pure"),
        !get("ikfac-bf16-pure").diverged
            && !get("singd:diag-bf16-pure").diverged
            && !get("ingd-bf16-pure").diverged
    );
    assert!(
        !get("ikfac-bf16").diverged && !get("singd:diag-bf16").diverged && !get("ingd-bf16").diverged,
        "inverse-free methods must not diverge in bf16"
    );
    assert!(
        !get("ikfac-bf16-pure").diverged && !get("singd:diag-bf16-pure").diverged,
        "inverse-free methods must not diverge even in PURE bf16"
    );
    assert!(
        (err("ikfac-fp32") - err("kfac-fp32")).abs() < 0.1,
        "IKFAC should track KFAC in fp32 at matched hypers (Theorem 1)"
    );
    // KFAC's low-precision pathology: Cholesky failures or divergence or a
    // clear error gap vs its own fp32 run.
    let kfac_sick = get("kfac-bf16-pure").diverged
        || !get("kfac-bf16-pure").telemetry.is_empty()
        || !get("kfac-bf16").telemetry.is_empty()
        || err("kfac-bf16") > err("kfac-fp32") + 0.03;
    assert!(kfac_sick, "expected KFAC to show low-precision instability");
}
