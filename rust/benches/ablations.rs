//! Ablations over SINGD's design choices (DESIGN.md §5 extension):
//!
//! 1. **Trace adaptivity** (INGD's `Tr(H_C)·H_K`/adaptive damping vs
//!    IKFAC's constants) at fixed structure — what §3.1's "these terms can
//!    contribute to stability" claims;
//! 2. **Riemannian momentum** `α₁ ∈ {0, 0.3, 0.6, 0.9}`;
//! 3. **Preconditioner refresh interval** `T ∈ {1, 5, 20}` — the
//!    amortization knob of §2.1 (cost ∝ 1/T, quality should degrade
//!    gracefully);
//! 4. **Optimizer zoo** (ISSUE 10) — RK-FAC (sketched Kronecker factors)
//!    and MAC (rank-1 mean-activation curvature) against the resident
//!    AdamW / KFAC / SINGD rows: per-step wall time, per-rank state
//!    bytes and the loss trajectory. The state-bytes ordering
//!    `mac < rkfac < kfac` is asserted here — it is the memory claim the
//!    zoo exists to demonstrate.
//!
//! (The Appendix-F Kronecker-rescaling invariance is exercised exactly in
//! `optim::singd::tests::invariance_of_ingd_to_kronecker_rescaling`.)
//!
//! Each run dumps machine-readable results to `BENCH_ablations.json` in
//! the repo root — in `--smoke` mode too (ci.sh regenerates the file on
//! every full pass so the zoo rows can never go stale; the `smoke` flag
//! inside the JSON marks rows whose timings are 1-epoch noise).
//!
//! Run: `cargo bench --bench ablations`
//! CI:  `cargo bench --bench ablations -- --smoke`

use singd::config::{Arch, JobConfig};
use singd::exp::{default_hyper, run_job};
use singd::optim::Method;
use singd::structured::Structure;
use singd::train::{RunResult, Schedule};

fn base(smoke: bool) -> JobConfig {
    let m = Method::Singd { structure: Structure::Diagonal };
    JobConfig {
        arch: Arch::Mlp { hidden: vec![64, 32] },
        dataset: "cifar100".into(),
        classes: 10,
        n_train: if smoke { 256 } else { 1000 },
        n_test: if smoke { 64 } else { 250 },
        method: m.clone(),
        hyper: default_hyper(&m, false),
        schedule: Schedule::Cosine { total: 300 },
        epochs: if smoke { 1 } else { 10 },
        batch_size: 32,
        seed: 77,
        label: "ablation".into(),
        ranks: 1,
        dist_strategy: singd::dist::DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
        algo: singd::dist::default_algo(),
        overlap: singd::dist::default_overlap(),
        stream: singd::dist::default_stream(),
        wire_dtype: singd::dist::default_wire_dtype(),
        resume: None,
        ckpt: None,
        ckpt_every: 0,
        accum_steps: 1,
        elastic: false,
        trace_dir: None,
        log: None,
    }
}

/// One optimizer-zoo JSON row.
struct ZooRow {
    method: String,
    state_bytes: usize,
    step_ms: f64,
    final_err: f32,
    best_err: f32,
    diverged: bool,
    loss_curve: Vec<f32>,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// At most 12 evenly spaced train-loss samples — enough to see the
/// trajectory shape without dumping every step.
fn sample_losses(res: &RunResult) -> Vec<f32> {
    let n = res.rows.len();
    if n == 0 {
        return Vec::new();
    }
    let take = n.min(12);
    (0..take).map(|i| res.rows[i * (n - 1) / (take - 1).max(1)].train_loss).collect()
}

fn write_json(zoo: &[ZooRow], csv_rows: &[(String, String, f32, f32, bool, f64)], smoke: bool) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"ablations\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str("  \"zoo\": [\n");
    for (i, r) in zoo.iter().enumerate() {
        let curve =
            r.loss_curve.iter().map(|l| format!("{l:.4}")).collect::<Vec<_>>().join(", ");
        out.push_str(&format!(
            "    {{\"method\": \"{}\", \"state_bytes\": {}, \"step_ms\": {:.3}, \
             \"final_err\": {:.4}, \"best_err\": {:.4}, \"diverged\": {}, \
             \"loss_curve\": [{curve}]}}{}\n",
            json_escape(&r.method),
            r.state_bytes,
            r.step_ms,
            r.final_err,
            r.best_err,
            r.diverged,
            if i + 1 < zoo.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"ablations\": [\n");
    for (i, (group, setting, fin, best, div, wall)) in csv_rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"group\": \"{}\", \"setting\": \"{}\", \"final_err\": {fin:.4}, \
             \"best_err\": {best:.4}, \"diverged\": {div}, \"wall_s\": {wall:.2}}}{}\n",
            json_escape(group),
            json_escape(setting),
            if i + 1 < csv_rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_ablations.json", &out) {
        Ok(()) => println!("-- wrote BENCH_ablations.json"),
        Err(e) => eprintln!("-- failed to write BENCH_ablations.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut csv = String::from("ablation,setting,final_err,best_err,diverged,wall_s\n");
    let mut rows: Vec<(String, String, f32, f32, bool, f64)> = Vec::new();
    let mut emit = |group: &str, setting: &str, cfg: &JobConfig| {
        let res = run_job(cfg);
        println!(
            "{group:<22} {setting:<16} final {:.3} best {:.3}{}",
            res.final_test_err,
            res.best_test_err,
            if res.diverged { "  DIVERGED" } else { "" }
        );
        csv.push_str(&format!(
            "{group},{setting},{},{},{},{:.2}\n",
            res.final_test_err, res.best_test_err, res.diverged as u8, res.wall_secs
        ));
        rows.push((
            group.into(),
            setting.into(),
            res.final_test_err,
            res.best_test_err,
            res.diverged,
            res.wall_secs,
        ));
        (res.best_test_err, res.diverged)
    };

    println!("== ablation 1: trace adaptivity (dense structure) ==");
    let mut cfg = base(smoke);
    cfg.method = Method::Singd { structure: Structure::Dense };
    cfg.hyper = default_hyper(&cfg.method, false);
    let (adaptive_err, _) = emit("adaptivity", "ingd(adaptive)", &cfg);
    cfg.method = Method::Ikfac { structure: Structure::Dense };
    cfg.hyper = default_hyper(&cfg.method, false);
    let (ikfac_err, _) = emit("adaptivity", "ikfac(fixed)", &cfg);
    println!("-> adaptive {adaptive_err:.3} vs fixed {ikfac_err:.3}\n");

    println!("== ablation 2: Riemannian momentum α₁ ==");
    for a1 in [0.0f32, 0.3, 0.6, 0.9] {
        let mut cfg = base(smoke);
        cfg.hyper.riem_momentum = a1;
        emit("riem_momentum", &format!("α₁={a1}"), &cfg);
    }
    println!();

    println!("== ablation 3: refresh interval T ==");
    let mut errs_t = Vec::new();
    for t in [1usize, 5, 20] {
        let mut cfg = base(smoke);
        cfg.hyper.t_update = t;
        let (e, d) = emit("t_update", &format!("T={t}"), &cfg);
        errs_t.push((t, e, d));
    }
    if !smoke {
        // Amortization must degrade gracefully: T=20 within 0.1 of T=1.
        // (Skipped in smoke mode — one epoch is all warm-up noise.)
        let e1 = errs_t[0].1;
        let e20 = errs_t[2].1;
        assert!(e20 < e1 + 0.1, "T=20 should stay close to T=1: {e1} vs {e20}");
    }
    println!();

    println!("== ablation 4: optimizer zoo (RK-FAC + MAC vs residents) ==");
    let mut zoo: Vec<ZooRow> = Vec::new();
    for method in [
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Diagonal },
        Method::RkFac { k: singd::optim::DEFAULT_SKETCH_RANK },
        Method::Mac,
    ] {
        let mut cfg = base(smoke);
        cfg.method = method.clone();
        cfg.hyper = default_hyper(&method, false);
        let res = run_job(&cfg);
        let step_ms = res.wall_secs * 1e3 / res.steps_run.max(1) as f64;
        println!(
            "{:<12} {:>10} B/rank  {step_ms:>8.3} ms/step  final {:.3}{}",
            method.name(),
            res.optimizer_bytes,
            res.final_test_err,
            if res.diverged { "  DIVERGED" } else { "" }
        );
        csv.push_str(&format!(
            "zoo,{},{},{},{},{:.2}\n",
            method.name(),
            res.final_test_err,
            res.best_test_err,
            res.diverged as u8,
            res.wall_secs
        ));
        zoo.push(ZooRow {
            method: method.name(),
            state_bytes: res.optimizer_bytes,
            step_ms,
            final_err: res.final_test_err,
            best_err: res.best_test_err,
            diverged: res.diverged,
            loss_curve: sample_losses(&res),
        });
    }
    // The memory claim the zoo demonstrates (ISSUE 10 acceptance):
    // rank-1 MAC < rank-k RK-FAC < dense-factor KFAC state bytes.
    let bytes =
        |name: &str| zoo.iter().find(|r| r.method == name).map(|r| r.state_bytes).unwrap();
    let (mac, rkfac, kfac) = (bytes("mac"), bytes("rkfac"), bytes("kfac"));
    assert!(
        mac < rkfac && rkfac < kfac,
        "zoo state-bytes ordering violated: mac {mac} !< rkfac {rkfac} !< kfac {kfac}"
    );

    singd::train::write_csv("ablations.csv", &csv).ok();
    write_json(&zoo, &rows, smoke);
}
