//! Ablations over SINGD's design choices (DESIGN.md §5 extension):
//!
//! 1. **Trace adaptivity** (INGD's `Tr(H_C)·H_K`/adaptive damping vs
//!    IKFAC's constants) at fixed structure — what §3.1's "these terms can
//!    contribute to stability" claims;
//! 2. **Riemannian momentum** `α₁ ∈ {0, 0.3, 0.6, 0.9}`;
//! 3. **Preconditioner refresh interval** `T ∈ {1, 5, 20}` — the
//!    amortization knob of §2.1 (cost ∝ 1/T, quality should degrade
//!    gracefully).
//!
//! (The Appendix-F Kronecker-rescaling invariance is exercised exactly in
//! `optim::singd::tests::invariance_of_ingd_to_kronecker_rescaling`.)
//!
//! Run: `cargo bench --bench ablations`

use singd::config::{Arch, JobConfig};
use singd::exp::{default_hyper, run_job};
use singd::optim::Method;
use singd::structured::Structure;
use singd::train::Schedule;

fn base() -> JobConfig {
    let m = Method::Singd { structure: Structure::Diagonal };
    JobConfig {
        arch: Arch::Mlp { hidden: vec![64, 32] },
        dataset: "cifar100".into(),
        classes: 10,
        n_train: 1000,
        n_test: 250,
        method: m.clone(),
        hyper: default_hyper(&m, false),
        schedule: Schedule::Cosine { total: 300 },
        epochs: 10,
        batch_size: 32,
        seed: 77,
        label: "ablation".into(),
        ranks: 1,
        dist_strategy: singd::dist::DistStrategy::Replicated,
        transport: singd::dist::Transport::Local,
        algo: singd::dist::default_algo(),
        overlap: singd::dist::default_overlap(),
        wire_dtype: singd::dist::default_wire_dtype(),
        resume: None,
        ckpt: None,
        ckpt_every: 0,
        elastic: false,
        trace_dir: None,
        log: None,
    }
}

fn main() {
    let mut csv = String::from("ablation,setting,final_err,best_err,diverged,wall_s\n");
    let mut emit = |group: &str, setting: &str, cfg: &JobConfig| {
        let res = run_job(cfg);
        println!(
            "{group:<22} {setting:<16} final {:.3} best {:.3}{}",
            res.final_test_err,
            res.best_test_err,
            if res.diverged { "  DIVERGED" } else { "" }
        );
        csv.push_str(&format!(
            "{group},{setting},{},{},{},{:.2}\n",
            res.final_test_err, res.best_test_err, res.diverged as u8, res.wall_secs
        ));
        (res.best_test_err, res.diverged)
    };

    println!("== ablation 1: trace adaptivity (dense structure) ==");
    let mut cfg = base();
    cfg.method = Method::Singd { structure: Structure::Dense };
    cfg.hyper = default_hyper(&cfg.method, false);
    let (adaptive_err, _) = emit("adaptivity", "ingd(adaptive)", &cfg);
    cfg.method = Method::Ikfac { structure: Structure::Dense };
    cfg.hyper = default_hyper(&cfg.method, false);
    let (ikfac_err, _) = emit("adaptivity", "ikfac(fixed)", &cfg);
    println!("-> adaptive {adaptive_err:.3} vs fixed {ikfac_err:.3}\n");

    println!("== ablation 2: Riemannian momentum α₁ ==");
    for a1 in [0.0f32, 0.3, 0.6, 0.9] {
        let mut cfg = base();
        cfg.hyper.riem_momentum = a1;
        emit("riem_momentum", &format!("α₁={a1}"), &cfg);
    }
    println!();

    println!("== ablation 3: refresh interval T ==");
    let mut errs_t = Vec::new();
    for t in [1usize, 5, 20] {
        let mut cfg = base();
        cfg.hyper.t_update = t;
        let (e, d) = emit("t_update", &format!("T={t}"), &cfg);
        errs_t.push((t, e, d));
    }
    // Amortization must degrade gracefully: T=20 within 0.1 of T=1.
    let e1 = errs_t[0].1;
    let e20 = errs_t[2].1;
    assert!(e20 < e1 + 0.1, "T=20 should stay close to T=1: {e1} vs {e20}");
    singd::train::write_csv("ablations.csv", &csv).ok();
}
