//! Fig. 7 — CNN models (ConvMixer-ish, Rep-ViT stand-in = VGG on
//! ImageWoof) in bf16 and a GNN on synthetic Cora in fp32 (the paper
//! trains the GNN in fp32 so KFAC can participate).
//!
//! Expected shape: SINGD (incl. Diag) ≥ AdamW on the CNNs; on the GNN,
//! KFAC-fp32 is a strong baseline and SINGD matches it.
//!
//! Scale with `SINGD_BENCH_EPOCHS` (default 6).
//! Run: `cargo bench --bench fig7_cnn_gnn`

use singd::config::{Arch, JobConfig};
use singd::exp::{cosine_for, default_hyper, run_gcn, run_grid};
use singd::optim::Method;
use singd::structured::Structure;

fn main() {
    let epochs: usize =
        std::env::var("SINGD_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let methods: Vec<_> = [
        Method::Sgd,
        Method::AdamW,
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::Hierarchical { k1: 4, k2: 4 } },
        Method::Singd { structure: Structure::Dense },
    ]
    .into_iter()
    .map(|m| (m.clone(), default_hyper(&m, true)))
    .collect();

    let mut all_csv = String::new();
    for (name, arch, ds, classes, n_train) in [
        ("convmixer/cifar100", Arch::ConvMixer { patch: 4, width: 16, depth: 2 }, "cifar100", 20usize, 900usize),
        ("vgg/imagewoof", Arch::Vgg { width: 8 }, "imagewoof", 10, 600),
    ] {
        println!("\n== Fig. 7 — {name}, bf16, {epochs} epochs ==");
        let base = JobConfig {
            arch,
            dataset: ds.into(),
            classes,
            n_train,
            n_test: 240,
            method: Method::Sgd,
            hyper: default_hyper(&Method::Sgd, true),
            schedule: cosine_for(epochs, n_train, 32),
            epochs,
            batch_size: 32,
            seed: 31,
            label: name.replace('/', "-"),
            ranks: 1,
            dist_strategy: singd::dist::DistStrategy::Replicated,
            transport: singd::dist::Transport::Local,
            algo: singd::dist::default_algo(),
            overlap: singd::dist::default_overlap(),
            wire_dtype: singd::dist::default_wire_dtype(),
            resume: None,
            ckpt: None,
            ckpt_every: 0,
            elastic: false,
            trace_dir: None,
            log: None,
        };
        let grid = run_grid(&base, &methods, &["bf16"]);
        for (label, res) in &grid {
            all_csv.push_str(&res.to_csv(&format!("{name}/{label}")));
        }
        let err =
            |l: &str| grid.iter().find(|(n, _)| n == l).map(|(_, r)| r.best_test_err).unwrap();
        let best_singd = ["singd:diag-bf16", "singd:hier:8-bf16", "ingd-bf16"]
            .iter()
            .map(|l| err(l))
            .fold(f32::INFINITY, f32::min);
        println!("\n{name}: best SINGD {:.3} vs AdamW {:.3} vs SGD {:.3}",
            best_singd, err("adamw-bf16"), err("sgd-bf16"));
        assert!(grid.iter().all(|(_, r)| !r.diverged), "{name}: bf16 stability");
        assert!(best_singd <= err("adamw-bf16") + 0.05, "{name}: SINGD ≥ AdamW (Fig. 7)");
    }
    singd::train::write_csv("fig7_cnn_curves.csv", &all_csv).ok();

    // -- GNN on Cora, fp32 (KFAC participates here, as in the paper) --
    println!("\n== Fig. 7 right — GCN on synthetic Cora, fp32 ==");
    let steps = 60 * epochs;
    let mut gnn_csv = String::from("method,step,test_loss,test_err\n");
    let mut finals = Vec::new();
    for method in [
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::Dense },
    ] {
        let mut hp = default_hyper(&method, false);
        hp.lr *= 3.0;
        let (curve, diverged) = run_gcn(&method, &hp, steps, 7);
        let last = curve.last().unwrap().2;
        println!("{:<14} final test err {:.3} diverged={}", method.name(), last, diverged);
        for (t, loss, err) in &curve {
            gnn_csv.push_str(&format!("{},{},{},{}\n", method.name(), t, loss, err));
        }
        finals.push((method.name(), last, diverged));
        assert!(!diverged, "{}: GNN fp32 run must be stable", method.name());
    }
    singd::train::write_csv("fig7_gnn_curves.csv", &gnn_csv).ok();
    let kfac = finals.iter().find(|(n, _, _)| n == "kfac").unwrap().1;
    let best_singd = finals
        .iter()
        .filter(|(n, _, _)| n.starts_with("singd") || n == "ingd")
        .map(|(_, e, _)| *e)
        .fold(f32::INFINITY, f32::min);
    println!("\nGNN: best SINGD {best_singd:.3} vs KFAC {kfac:.3}");
    assert!(best_singd <= kfac + 0.08, "SINGD should match KFAC on the GNN (Fig. 7)");
}
