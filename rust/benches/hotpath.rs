//! §Perf microbenchmarks — the L3 hot paths.
//!
//! 1. `tensor::matmul` (model fwd/bwd substrate) across sizes;
//! 2. structured factor ops (`gram_project`, `matmul`, `kkt_right`);
//! 3. full optimizer steps (KFAC vs INGD vs SINGD-Diag/Hier);
//! 4. PJRT engine call overhead (when artifacts are built).
//!
//! Before/after numbers for each optimization iteration are logged in
//! EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench hotpath`

use singd::bench::{black_box, Harness};
use singd::optim::{Hyper, KronStats, Method, Optimizer};
use singd::proptest::Pcg;
use singd::structured::{SMat, Structure};
use singd::tensor::{matmul, Mat};

fn main() {
    let mut h = Harness::new("hotpath");
    h.target_secs = 0.4;
    let mut rng = Pcg::new(3);

    // 1. matmul GFLOP/s.
    for n in [64usize, 128, 256, 512] {
        let a = rng.normal_mat(n, n, 1.0);
        let b = rng.normal_mat(n, n, 1.0);
        let st = h.bench(&format!("matmul {n}x{n}x{n}"), || {
            black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / st.median_ns;
        println!("{:>46} {:.2} GFLOP/s", "->", gflops);
    }

    // 2. structured ops at d = 256.
    let d = 256;
    let m = 64;
    let a_rows = rng.normal_mat(m, d, 1.0);
    let x = rng.normal_mat(16, d, 1.0);
    for s in [
        Structure::Dense,
        Structure::BlockDiag { k: 32 },
        Structure::Hierarchical { k1: 8, k2: 8 },
        Structure::RankKTril { k: 1 },
        Structure::TriuToeplitz,
        Structure::Diagonal,
    ] {
        // Fully-populated factor (identity would hit the zero-skip fast
        // paths and understate cost).
        let sym = rng.normal_mat(d, d, 0.2).symmetrize();
        let mut k = singd::structured::proj::proj(s, &sym);
        k.axpy(1.0, &SMat::identity(s, d));
        h.bench(&format!("gram_project {} d={d} m={m}", s.name()), || {
            black_box(k.gram_project(&a_rows, 1.0));
        });
        h.bench(&format!("kkt_right {} d={d}", s.name()), || {
            black_box(k.kkt_right(&x));
        });
        let k2 = SMat::identity(s, d);
        h.bench(&format!("struct matmul {} d={d}", s.name()), || {
            black_box(k.matmul(&k2));
        });
    }

    // 3. full optimizer steps on a (256, 256) layer.
    let shapes = [(d, d)];
    let grads = [rng.normal_mat(d, d, 0.1)];
    let stats = [KronStats { a: rng.normal_mat(m, d, 1.0), g: rng.normal_mat(m, d, 1.0) }];
    for method in [
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Dense },
        Method::Singd { structure: Structure::Hierarchical { k1: 8, k2: 8 } },
        Method::Singd { structure: Structure::Diagonal },
    ] {
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = method.build(&shapes, &hp);
        let mut params = [rng.normal_mat(d, d, 0.1)];
        let mut t = 0usize;
        h.bench(&format!("optimizer step {} d={d} T=1", method.name()), || {
            opt.step(t, &mut params, &grads, &stats);
            t += 1;
        });
    }

    // 4. PJRT call overhead (optional — needs `make artifacts`).
    let smoke = singd::runtime::artifact_path("smoke.hlo.txt");
    if std::path::Path::new(&smoke).exists() {
        let eng = singd::runtime::Engine::load(&smoke).expect("load smoke artifact");
        let x = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = Mat::ones(2, 2);
        h.bench("pjrt roundtrip (2x2 smoke)", || {
            black_box(
                eng.run(&[singd::runtime::MatInput::new(&x), singd::runtime::MatInput::new(&y)])
                    .unwrap(),
            );
        });
    } else {
        println!("(skipping PJRT bench — run `make artifacts`)");
    }

    h.finish();
}
