//! §Perf microbenchmarks — the L3 hot paths.
//!
//! 1. `tensor::matmul` (model fwd/bwd substrate) across sizes, plus the
//!    per-step Kronecker-statistics products `matmul_at_b` / `matmul_a_bt`;
//! 2. structured factor ops (`gram_project`, `matmul`, `kkt_right`);
//! 3. full optimizer steps (KFAC vs INGD vs SINGD-Diag/Hier);
//! 4. PJRT engine call overhead (when artifacts are built and the crate
//!    is compiled with `--features pjrt`).
//!
//! Before/after numbers for each optimization iteration are logged in
//! EXPERIMENTS.md §Perf; each run also dumps machine-readable results to
//! `BENCH_hotpath.json` in the repo root.
//!
//! Run: `cargo bench --bench hotpath`
//! CI:  `cargo bench --bench hotpath -- --smoke`   (one iteration per case)

use singd::bench::{black_box, Harness, Stats};
use singd::optim::{Hyper, KronStats, Method, Optimizer};
use singd::proptest::Pcg;
use singd::structured::{SMat, Structure};
use singd::tensor::{matmul, matmul_a_bt, matmul_at_b, pool};

/// One JSON row: timing stats plus optional GFLOP/s.
struct Row {
    stats: Stats,
    gflops: Option<f64>,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(rows: &[Row], smoke: bool) {
    let mut out = String::from("{\n");
    out.push_str("  \"bench\": \"hotpath\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str(&format!("  \"threads\": {},\n", pool::num_threads()));
    out.push_str("  \"cases\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let s = &row.stats;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \"min_ns\": {:.1}, \"max_ns\": {:.1}",
            json_escape(&s.name),
            s.iters,
            s.median_ns,
            s.mean_ns,
            s.min_ns,
            s.max_ns
        ));
        match row.gflops {
            Some(g) => out.push_str(&format!(", \"gflops\": {g:.3}}}")),
            None => out.push('}'),
        }
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_hotpath.json", &out) {
        Ok(()) => println!("-- wrote BENCH_hotpath.json"),
        Err(e) => eprintln!("-- failed to write BENCH_hotpath.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut h = Harness::new("hotpath");
    if smoke {
        h.target_secs = 0.0;
        h.max_iters = 1;
    } else {
        h.target_secs = 0.4;
    }
    let mut rows: Vec<Row> = Vec::new();
    let mut rng = Pcg::new(3);

    // 1a. square matmul GFLOP/s.
    for n in [64usize, 128, 256, 512] {
        let a = rng.normal_mat(n, n, 1.0);
        let b = rng.normal_mat(n, n, 1.0);
        let st = h.bench(&format!("matmul {n}x{n}x{n}"), || {
            black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / st.median_ns;
        println!("{:>46} {:.2} GFLOP/s", "->", gflops);
        rows.push(Row { stats: st, gflops: Some(gflops) });
    }

    // 1b. Kronecker-statistics products at the paper's transformer-ish
    // shape: X ∈ R^{4096×512} (batch·seq × width).
    {
        let (m, d) = (4096usize, 512usize);
        let x = rng.normal_mat(m, d, 1.0);
        let y = rng.normal_mat(m, d, 1.0);
        let st = h.bench(&format!("matmul_at_b {m}x{d}"), || {
            black_box(matmul_at_b(&x, &y));
        });
        let gflops = 2.0 * (m as f64) * (d as f64) * (d as f64) / st.median_ns;
        println!("{:>46} {:.2} GFLOP/s", "->", gflops);
        rows.push(Row { stats: st, gflops: Some(gflops) });

        let w = rng.normal_mat(d, d, 1.0);
        let st = h.bench(&format!("matmul_a_bt {m}x{d} @ {d}x{d}T"), || {
            black_box(matmul_a_bt(&x, &w));
        });
        let gflops = 2.0 * (m as f64) * (d as f64) * (d as f64) / st.median_ns;
        println!("{:>46} {:.2} GFLOP/s", "->", gflops);
        rows.push(Row { stats: st, gflops: Some(gflops) });
    }

    // 2. structured ops at d = 256.
    let d = 256;
    let m = 64;
    let a_rows = rng.normal_mat(m, d, 1.0);
    let x = rng.normal_mat(16, d, 1.0);
    for s in [
        Structure::Dense,
        Structure::BlockDiag { k: 32 },
        Structure::Hierarchical { k1: 8, k2: 8 },
        Structure::RankKTril { k: 1 },
        Structure::TriuToeplitz,
        Structure::Diagonal,
    ] {
        // Fully-populated factor (identity would hit the zero-skip fast
        // paths and understate cost).
        let sym = rng.normal_mat(d, d, 0.2).symmetrize();
        let mut k = singd::structured::proj::proj(s, &sym);
        k.axpy(1.0, &SMat::identity(s, d));
        let st = h.bench(&format!("gram_project {} d={d} m={m}", s.name()), || {
            black_box(k.gram_project(&a_rows, 1.0));
        });
        rows.push(Row { stats: st, gflops: None });
        let st = h.bench(&format!("kkt_right {} d={d}", s.name()), || {
            black_box(k.kkt_right(&x));
        });
        rows.push(Row { stats: st, gflops: None });
        let k2 = SMat::identity(s, d);
        let st = h.bench(&format!("struct matmul {} d={d}", s.name()), || {
            black_box(k.matmul(&k2));
        });
        rows.push(Row { stats: st, gflops: None });
    }

    // 3. full optimizer steps on a (256, 256) layer.
    let shapes = [(d, d)];
    let grads = [rng.normal_mat(d, d, 0.1)];
    let stats = [KronStats { a: rng.normal_mat(m, d, 1.0), g: rng.normal_mat(m, d, 1.0) }];
    for method in [
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Dense },
        Method::Singd { structure: Structure::Hierarchical { k1: 8, k2: 8 } },
        Method::Singd { structure: Structure::Diagonal },
    ] {
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = method.build(&shapes, &hp);
        let mut params = [rng.normal_mat(d, d, 0.1)];
        let mut t = 0usize;
        let st = h.bench(&format!("optimizer step {} d={d} T=1", method.name()), || {
            opt.step(t, &mut params, &grads, &stats);
            t += 1;
        });
        rows.push(Row { stats: st, gflops: None });
    }

    // 4. PJRT call overhead (needs `make artifacts` + `--features pjrt`).
    if cfg!(feature = "pjrt") {
        let smoke_artifact = singd::runtime::artifact_path("smoke.hlo.txt");
        if std::path::Path::new(&smoke_artifact).exists() {
            let eng = singd::runtime::Engine::load(&smoke_artifact).expect("load smoke artifact");
            let x = singd::Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
            let y = singd::Mat::ones(2, 2);
            let st = h.bench("pjrt roundtrip (2x2 smoke)", || {
                black_box(
                    eng.run(&[
                        singd::runtime::MatInput::new(&x),
                        singd::runtime::MatInput::new(&y),
                    ])
                    .unwrap(),
                );
            });
            rows.push(Row { stats: st, gflops: None });
        } else {
            println!("(skipping PJRT bench — run `make artifacts`)");
        }
    } else {
        println!("(skipping PJRT bench — built without the `pjrt` feature)");
    }

    if smoke {
        // Don't clobber the committed full-run numbers with 1-iteration
        // smoke noise (ci.sh runs --smoke on every pass).
        println!("-- smoke mode: skipping BENCH_hotpath.json");
    } else {
        write_json(&rows, smoke);
    }
    h.finish();
}
