//! Rank-invariance determinism suite for the distributed subsystem.
//!
//! Extends the serial/pooled bitwise-parity contract of
//! `rust/tests/parallel.rs` across world sizes: for power-of-two rank
//! counts dividing the batch size, the data-parallel driver must produce
//! *bitwise* identical losses and parameters to the serial path — under
//! both the replicated and factor-sharded strategies, on both rank
//! execution paths (pool workers and dedicated scoped threads).

use singd::data;
use singd::dist::{
    self, bucket, collectives, transport, Algo, Communicator, DistCtx, DistStrategy, Transport,
};
use singd::model::cnn::ImgShape;
use singd::model::{Mlp, Model};
use singd::numerics::Dtype;
use singd::optim::{Hyper, Method, Optimizer};
use singd::proptest::Pcg;
use singd::structured::Structure;
use singd::tensor::{pool, Mat};
use singd::train::{train_dist, train_image_model, DistCfg, RunResult, TrainCfg};

/// A 4-layer MLP job whose shapes satisfy the bitwise contract: batch 32
/// (power of two, divisible by 4 ranks), per-layer stats rows = 32.
fn fixture() -> (singd::data::Dataset, TrainCfg) {
    let mut rng = Pcg::new(2024);
    let ds = data::prototype_images(&mut rng, ImgShape { c: 1, h: 8, w: 8 }, 4, 128, 32, 2.0);
    let cfg = TrainCfg {
        method: Method::Singd { structure: Structure::Dense },
        hyper: Hyper { lr: 0.05, t_update: 1, riem_momentum: 0.6, ..Hyper::default() },
        epochs: 2,
        batch_size: 32,
        seed: 9,
        ..TrainCfg::default()
    };
    (ds, cfg)
}

fn fresh_model() -> Mlp {
    let mut rng = Pcg::new(77);
    Mlp::new(&mut rng, &[64, 48, 32, 16, 4])
}

/// Train from the fixed init; return the result and final parameters.
fn run(cfg: &TrainCfg, ds: &singd::data::Dataset, dc: Option<&DistCfg>) -> (RunResult, Vec<Mat>) {
    let mut model = fresh_model();
    let res = match dc {
        None => train_image_model(&mut model, ds, cfg),
        Some(dc) => train_dist(&mut model, ds, cfg, dc),
    };
    let params = model.params().clone();
    (res, params)
}

fn assert_bitwise_equal(a: &(RunResult, Vec<Mat>), b: &(RunResult, Vec<Mat>), ctx: &str) {
    assert_eq!(a.0.rows.len(), b.0.rows.len(), "{ctx}: row count");
    for (ra, rb) in a.0.rows.iter().zip(&b.0.rows) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{ctx}: train_loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.test_loss.to_bits(),
            rb.test_loss.to_bits(),
            "{ctx}: test_loss at step {}",
            ra.step
        );
        assert_eq!(ra.test_err.to_bits(), rb.test_err.to_bits(), "{ctx}: test_err");
    }
    assert_eq!(a.1.len(), b.1.len(), "{ctx}: layer count");
    for (l, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        assert!(pa.data() == pb.data(), "{ctx}: params of layer {l} diverged");
    }
}

#[test]
fn ranks1_is_bitwise_identical_to_serial() {
    let (ds, cfg) = fixture();
    let serial = run(&cfg, &ds, None);
    let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
    assert_bitwise_equal(&serial, &d1, "serial vs ranks=1");
}

#[test]
fn ranks4_replicated_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
    let d4 = run(&cfg, &ds, Some(&DistCfg::local(4, DistStrategy::Replicated)));
    assert_bitwise_equal(&d1, &d4, "ranks=1 vs ranks=4 replicated");
}

#[test]
fn ranks4_factor_sharded_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
    let d4 = run(&cfg, &ds, Some(&DistCfg::local(4, DistStrategy::FactorSharded)));
    assert_bitwise_equal(&d1, &d4, "ranks=1 vs ranks=4 factor-sharded");
}

#[test]
fn ranks2_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let d2 = run(&cfg, &ds, Some(&DistCfg::local(2, strategy)));
        assert_bitwise_equal(&d1, &d2, &format!("ranks=2 {}", strategy.name()));
    }
}

#[test]
fn singd_ranks_env_default_drives_dist_cfg_and_keeps_the_contract() {
    // ci.sh runs this suite under SINGD_RANKS ∈ {1, 4}: the env value
    // must flow into DistCfg::default() and the resulting world size
    // must uphold the bitwise contract against an explicit ranks=1 run.
    let mut dc = DistCfg::default();
    assert_eq!(dc.ranks, dist::default_ranks());
    assert_eq!(dc.transport, dist::default_transport());
    assert_eq!(dc.algo, dist::default_algo());
    // Under SINGD_TRANSPORT=socket the default would re-exec this test
    // binary as worker ranks; the multi-process leg lives in
    // rust/tests/dist_proc.rs (driving the singd binary), so this test
    // pins the in-process transport and checks the world-size default.
    dc.transport = Transport::Local;
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    if dc.ranks.is_power_of_two() && cfg.batch_size % dc.ranks == 0 {
        let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
        let denv = run(&cfg, &ds, Some(&dc));
        assert_bitwise_equal(&d1, &denv, &format!("SINGD_RANKS={} default", dc.ranks));
    }
}

#[test]
fn kfac_rank_invariance() {
    let (ds, mut cfg) = fixture();
    cfg.method = Method::Kfac;
    cfg.hyper = Hyper { lr: 0.01, damping: 0.1, t_update: 1, update_clip: 0.05, ..Hyper::default() };
    cfg.epochs = 1;
    let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let d4 = run(&cfg, &ds, Some(&DistCfg::local(4, strategy)));
        assert_bitwise_equal(&d1, &d4, &format!("kfac ranks=4 {}", strategy.name()));
    }
}

#[test]
fn rank_execution_path_does_not_change_results() {
    // with_threads(4): ranks run on pool workers (when the pool is large
    // enough); with_threads(1): ranks run on dedicated scoped threads.
    // The collectives order reductions by rank index, so both paths must
    // be bitwise identical.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let dc = DistCfg::local(4, DistStrategy::FactorSharded);
    let pooled = pool::with_threads(4, || run(&cfg, &ds, Some(&dc)));
    let threaded = pool::with_threads(1, || run(&cfg, &ds, Some(&dc)));
    assert_bitwise_equal(&pooled, &threaded, "pool vs scoped-thread ranks");
}

#[test]
fn factor_sharded_per_rank_state_shrinks_with_world_size() {
    let hp = Hyper::default();
    let method = Method::Singd { structure: Structure::Dense };
    // Heterogeneous layers: ranks partition the replicated state exactly.
    let mixed: Vec<(usize, usize)> = vec![(48, 64), (64, 96), (32, 48), (16, 32)];
    let full_mixed = method.build(&mixed, &hp).state_bytes();
    for world in [2usize, 4] {
        let per_rank: Vec<usize> = (0..world)
            .map(|r| {
                method
                    .build_dist(&mixed, &hp, DistCtx::new(DistStrategy::FactorSharded, r, world))
                    .state_bytes()
            })
            .collect();
        assert_eq!(per_rank.iter().sum::<usize>(), full_mixed, "world {world}");
    }
    // Equal layers: every rank holds exactly 1/world of the state.
    let equal: Vec<(usize, usize)> = vec![(32, 32); 8];
    let full_equal = method.build(&equal, &hp).state_bytes();
    for world in [2usize, 4, 8] {
        for r in 0..world {
            let b = method
                .build_dist(&equal, &hp, DistCtx::new(DistStrategy::FactorSharded, r, world))
                .state_bytes();
            assert_eq!(b * world, full_equal, "world {world} rank {r}");
        }
    }
}

#[test]
fn replicated_strategy_keeps_full_state_on_every_rank() {
    let shapes: Vec<(usize, usize)> = vec![(16, 16); 4];
    let hp = Hyper::default();
    let method = Method::Kfac;
    let full = method.build(&shapes, &hp).state_bytes();
    let r0 = method
        .build_dist(&shapes, &hp, DistCtx::new(DistStrategy::Replicated, 0, 4))
        .state_bytes();
    assert_eq!(r0, full);
}

#[test]
fn run_ranks_panic_propagates_and_pool_survives() {
    let out = std::panic::catch_unwind(|| {
        dist::run_ranks(4, |comm| {
            if comm.rank() == 2 {
                panic!("rank 2 exploded");
            }
            // Peers block on a collective; the poison must wake them.
            let _ = comm.exchange_f64(vec![comm.rank() as f64]);
        });
    });
    assert!(out.is_err(), "panic must propagate to the caller");
    // The pool and a fresh rendezvous must remain fully usable.
    let again = dist::run_ranks(4, |comm| {
        let parts = comm.exchange_f64(vec![comm.rank() as f64]);
        parts.iter().map(|p| p[0]).sum::<f64>()
    });
    assert_eq!(again, vec![6.0; 4]);
}

#[test]
fn bucketed_exchange_equals_per_layer_exchange_under_training_shapes() {
    // The exact shapes the factor-sharded driver exchanges: zero-padded
    // per-layer parameter updates of a 4-layer MLP.
    let mut rng = Pcg::new(31);
    let shapes = [(48usize, 65usize), (32, 49), (16, 33), (4, 17)];
    let world = 4;
    let values: Vec<Mat> = shapes.iter().map(|&(o, i)| rng.normal_mat(o, i, 0.1)).collect();
    let vals = &values;
    let outs = dist::run_ranks(world, |comm| {
        let mine: Vec<Mat> = vals
            .iter()
            .enumerate()
            .map(|(l, v)| {
                if dist::shard::round_robin_owner(l, world) == comm.rank() {
                    v.clone()
                } else {
                    Mat::zeros(v.rows(), v.cols())
                }
            })
            .collect();
        let mut bucketed = mine.clone();
        bucket::all_reduce_sum_bucketed(&comm, &mut bucketed, 1000);
        let plain = collectives::all_reduce_sum(&comm, &mine);
        (bucketed, plain)
    });
    for (bucketed, plain) in outs {
        for (l, ((b, p), want)) in bucketed.iter().zip(&plain).zip(vals).enumerate() {
            assert!(b.data() == p.data(), "layer {l}: bucketing changed bits");
            assert!(b.data() == want.data(), "layer {l}: zero-padded exchange not exact");
        }
    }
}

// =====================================================================
// Cross-transport conformance: every collective over SocketComm must be
// bitwise identical to LocalComm (ISSUE 3). The socket harness runs real
// Unix-domain sockets inside this process — the byte path is exactly the
// multi-process one (rust/tests/dist_proc.rs covers process isolation).

/// One rank's outputs from every collective, on fixed per-rank inputs.
/// Inputs include empty lists, empty (0-row) matrices and 1×1 buffers.
#[allow(clippy::type_complexity)]
fn all_collectives(
    comm: &dyn Communicator,
    seed: u64,
) -> (Vec<Mat>, Vec<Mat>, Vec<Mat>, Mat, Mat, Mat, Vec<f64>) {
    let mut rng = Pcg::with_stream(seed, comm.rank() as u64);
    let dense = rng.normal_mat(5, 3, 1.0);
    let one = Mat::from_vec(1, 1, vec![rng.normal()]);
    let empty_rows = Mat::zeros(0, 4);
    // all_reduce over a mixed list (dense, 1×1, 0-row).
    let reduced =
        collectives::all_reduce_sum(comm, &[dense.clone(), one.clone(), empty_rows.clone()]);
    // all_reduce over an empty list.
    let reduced_empty = collectives::all_reduce_sum(comm, &[]);
    // broadcast from a non-zero root.
    let root = 1 % comm.world_size();
    let payload = if comm.rank() == root { vec![dense.clone(), one.clone()] } else { Vec::new() };
    let bcast = collectives::broadcast(comm, root, payload);
    // all_gather_rows of per-rank 2×3 blocks and of 1×1 blocks.
    let gathered = collectives::all_gather_rows(comm, &rng.normal_mat(2, 3, 1.0));
    let gathered_tiny = collectives::all_gather_rows(comm, &one);
    // reduce_scatter with a non-dividing row count (7 rows).
    let scattered = collectives::reduce_scatter_rows(comm, &rng.normal_mat(7, 2, 1.0));
    // scalar exchange incl. the empty barrier.
    comm.barrier();
    let scal = comm.exchange_f64(vec![rng.normal() as f64]);
    let scalars: Vec<f64> = scal.iter().map(|p| p[0]).collect();
    (reduced, reduced_empty, bcast, gathered, gathered_tiny, scattered, scalars)
}

fn assert_mats_bitwise(a: &[Mat], b: &[Mat], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: list length");
    for (i, (ma, mb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ma.shape(), mb.shape(), "{ctx}[{i}]: shape");
        assert!(ma.data() == mb.data(), "{ctx}[{i}]: bits diverged");
    }
}

#[test]
fn socket_collectives_bitwise_match_local() {
    for world in [2usize, 4] {
        let seed = 1000 + world as u64;
        let local = dist::run_ranks(world, |c| all_collectives(&c, seed));
        let socket = transport::run_ranks_socket(world, |c| all_collectives(&c, seed));
        for (rank, (l, s)) in local.iter().zip(&socket).enumerate() {
            let ctx = format!("world {world} rank {rank}");
            assert_mats_bitwise(&l.0, &s.0, &format!("{ctx}: all_reduce"));
            assert_mats_bitwise(&l.1, &s.1, &format!("{ctx}: all_reduce empty"));
            assert_mats_bitwise(&l.2, &s.2, &format!("{ctx}: broadcast"));
            assert_mats_bitwise(
                std::slice::from_ref(&l.3),
                std::slice::from_ref(&s.3),
                &format!("{ctx}: all_gather_rows"),
            );
            assert_mats_bitwise(
                std::slice::from_ref(&l.4),
                std::slice::from_ref(&s.4),
                &format!("{ctx}: all_gather_rows 1x1"),
            );
            assert_mats_bitwise(
                std::slice::from_ref(&l.5),
                std::slice::from_ref(&s.5),
                &format!("{ctx}: reduce_scatter"),
            );
            assert_eq!(l.6.len(), s.6.len(), "{ctx}: scalars");
            for (x, y) in l.6.iter().zip(&s.6) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: scalar bits");
            }
        }
    }
}

#[test]
fn socket_bucketed_all_reduce_bitwise_matches_local() {
    let world = 4;
    let seed = 77u64;
    let body = |comm: &dyn Communicator| -> Vec<Mat> {
        let mut rng = Pcg::with_stream(seed, comm.rank() as u64);
        let mut mats: Vec<Mat> = [(3usize, 4usize), (1, 1), (8, 2), (0, 5), (2, 2)]
            .iter()
            .map(|&(r, c)| rng.normal_mat(r, c, 1.0))
            .collect();
        bucket::all_reduce_sum_bucketed(comm, &mut mats, 16);
        mats
    };
    let local = dist::run_ranks(world, |c| body(&c));
    let socket = transport::run_ranks_socket(world, |c| body(&c));
    for (rank, (l, s)) in local.iter().zip(&socket).enumerate() {
        assert_mats_bitwise(l, s, &format!("bucketed rank {rank}"));
    }
}

// =====================================================================
// Ring-vs-star conformance (ISSUE 4): the ring schedules reduce every
// chunk at its destination with the same halving tree the star uses, so
// every collective must be bitwise identical across algo ∈ {star, ring}
// × transport ∈ {local, socket} — on randomized shapes including empty
// matrices, 1×1 buffers, and row/element counts the chunk plan does not
// divide evenly (and worlds larger than the payload, where trailing
// chunks are empty).

/// One rank's outputs from every algo-dispatched collective on seeded
/// per-rank random inputs of the given shapes.
#[allow(clippy::type_complexity)]
fn algo_collectives(
    comm: &dyn Communicator,
    seed: u64,
    shapes: &[(usize, usize)],
) -> (Vec<Mat>, Vec<Mat>, Mat, Mat, Vec<Mat>) {
    let mut rng = Pcg::with_stream(seed, comm.rank() as u64);
    let mats: Vec<Mat> = shapes.iter().map(|&(r, c)| rng.normal_mat(r, c, 1.0)).collect();
    let reduced = collectives::all_reduce_sum(comm, &mats);
    let mut bucketed = mats.clone();
    bucket::all_reduce_sum_bucketed(comm, &mut bucketed, 1 + seed as usize % 37);
    // A second matrix with a row count the world rarely divides.
    let tall = rng.normal_mat(1 + seed as usize % 9, 1 + seed as usize % 4, 1.0);
    let gathered = collectives::all_gather_rows(comm, &tall);
    let scattered = collectives::reduce_scatter_rows(comm, &tall);
    let root = (seed as usize) % comm.world_size();
    let payload = if comm.rank() == root { mats.clone() } else { Vec::new() };
    let bcast = collectives::broadcast(comm, root, payload);
    (reduced, bucketed, gathered, scattered, bcast)
}

#[test]
fn ring_and_star_agree_bitwise_across_transports_on_randomized_shapes() {
    let mut shape_rng = Pcg::new(0xa190);
    for world in [2usize, 3, 4] {
        for trial in 0..4 {
            // Random shape lists seeded per (world, trial): include the
            // degenerate shapes (0×k rows, k×0 cols, 1×1) by sampling
            // dims in 0..=6 and forcing a 1×1 and a 0-row entry.
            let mut shapes: Vec<(usize, usize)> = (0..2 + shape_rng.below(3))
                .map(|_| (shape_rng.below(7), shape_rng.below(7)))
                .collect();
            shapes.push((1, 1));
            shapes.push((0, 3));
            let seed = 7000 + (world * 100 + trial) as u64;
            let sh = &shapes;
            let star_local =
                dist::run_ranks_algo(world, Algo::Star, |c| algo_collectives(&c, seed, sh));
            let ring_local =
                dist::run_ranks_algo(world, Algo::Ring, |c| algo_collectives(&c, seed, sh));
            let star_socket = transport::run_ranks_socket_algo(world, Algo::Star, |c| {
                algo_collectives(&c, seed, sh)
            });
            let ring_socket = transport::run_ranks_socket_algo(world, Algo::Ring, |c| {
                algo_collectives(&c, seed, sh)
            });
            let variants = [
                ("ring-local", &ring_local),
                ("star-socket", &star_socket),
                ("ring-socket", &ring_socket),
            ];
            for (name, variant) in variants {
                for (rank, (a, b)) in star_local.iter().zip(variant.iter()).enumerate() {
                    let ctx = format!("world {world} trial {trial} rank {rank} {name}");
                    assert_mats_bitwise(&a.0, &b.0, &format!("{ctx}: all_reduce"));
                    assert_mats_bitwise(&a.1, &b.1, &format!("{ctx}: bucketed all_reduce"));
                    assert_mats_bitwise(
                        std::slice::from_ref(&a.2),
                        std::slice::from_ref(&b.2),
                        &format!("{ctx}: all_gather_rows"),
                    );
                    assert_mats_bitwise(
                        std::slice::from_ref(&a.3),
                        std::slice::from_ref(&b.3),
                        &format!("{ctx}: reduce_scatter_rows"),
                    );
                    assert_mats_bitwise(&a.4, &b.4, &format!("{ctx}: broadcast"));
                }
            }
        }
    }
}

#[test]
fn ring_training_is_bitwise_identical_to_star_and_serial() {
    // The end-to-end acceptance: the same fixture trained under
    // --algo ring matches --algo star and the serial path bit for bit,
    // for both strategies.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let mut star = DistCfg::local(4, strategy);
        star.algo = Algo::Star;
        let mut ring = DistCfg::local(4, strategy);
        ring.algo = Algo::Ring;
        let star_run = run(&cfg, &ds, Some(&star));
        let ring_run = run(&cfg, &ds, Some(&ring));
        assert_bitwise_equal(&serial, &star_run, &format!("star {}", strategy.name()));
        assert_bitwise_equal(&serial, &ring_run, &format!("ring {}", strategy.name()));
    }
}

// =====================================================================
// Property-style randomized bucket tests (seeded Pcg, no wall clock).

#[test]
fn bucket_plan_property_bound_and_coverage() {
    let mut rng = Pcg::new(0x5eed);
    for trial in 0..50 {
        let n = 1 + rng.below(12);
        let sizes: Vec<usize> = (0..n)
            .map(|_| if rng.below(8) == 0 { 0 } else { 1 + rng.below(200) })
            .collect();
        let cap = 1 + rng.below(64);
        let plan = bucket::BucketPlan::new(&sizes, cap);
        // Coverage: concatenated ranges are exactly 0..n, in order.
        let mut next = 0usize;
        for b in &plan.buckets {
            assert_eq!(b.start, next, "trial {trial}");
            assert!(b.end > b.start, "trial {trial}: empty bucket");
            next = b.end;
        }
        assert_eq!(next, sizes.len(), "trial {trial}");
        // Byte bound: a bucket exceeds the cap only when it holds a
        // single oversized layer, so the max bucket never exceeds
        // max(cap, largest layer).
        let largest = sizes.iter().copied().max().unwrap_or(0);
        assert!(
            plan.max_bucket_elems(&sizes) <= cap.max(largest),
            "trial {trial}: bound violated"
        );
        for b in &plan.buckets {
            let total: usize = sizes[b.clone()].iter().sum();
            assert!(total <= cap || b.len() == 1, "trial {trial}: multi-layer bucket over cap");
        }
    }
}

#[test]
fn bucket_roundtrip_property_random_layer_sequences() {
    // Arbitrary layer-size sequences must coalesce/scatter losslessly:
    // the bucketed all-reduce returns exactly the per-layer all-reduce,
    // bit for bit, for every capacity (including caps smaller than the
    // largest layer — the single-layer-overflow edge case).
    let mut rng = Pcg::new(0xb0c4e7);
    for trial in 0..10 {
        let world = [2usize, 4][trial % 2];
        let n = 1 + rng.below(7);
        let shapes: Vec<(usize, usize)> =
            (0..n).map(|_| (rng.below(9), 1 + rng.below(9))).collect();
        let caps = [1usize, 1 + rng.below(40), 1 << 20];
        let inputs: Vec<Vec<Mat>> = (0..world)
            .map(|_| shapes.iter().map(|&(r, c)| rng.normal_mat(r, c, 1.0)).collect())
            .collect();
        let inp = &inputs;
        for &cap in &caps {
            let outs = dist::run_ranks(world, |comm| {
                let mut bucketed = inp[comm.rank()].clone();
                bucket::all_reduce_sum_bucketed(&comm, &mut bucketed, cap);
                let plain = collectives::all_reduce_sum(&comm, &inp[comm.rank()]);
                (bucketed, plain)
            });
            for (rank, (bucketed, plain)) in outs.iter().enumerate() {
                assert_mats_bitwise(
                    bucketed,
                    plain,
                    &format!("trial {trial} cap {cap} rank {rank}"),
                );
            }
        }
    }
}

#[test]
fn bucket_single_layer_larger_than_bucket_travels_alone() {
    let sizes = [300usize, 4, 4];
    let plan = bucket::BucketPlan::new(&sizes, 16);
    assert_eq!(plan.buckets[0], 0..1, "oversized layer must travel alone");
    assert_eq!(plan.max_bucket_elems(&sizes), 300);
}

// =====================================================================
// Fault injection: a dead rank must wake every peer with an error, not
// a deadlock — asserted through a timeout harness on both transports.

/// Run `f` on a watchdog thread; returns `Some(panicked)` if it finished
/// within `secs`, `None` on timeout (the deadlock verdict).
fn finishes_within<F: FnOnce() + Send + 'static>(secs: u64, f: F) -> Option<bool> {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        let _ = tx.send(out.is_err());
    });
    rx.recv_timeout(std::time::Duration::from_secs(secs)).ok()
}

#[test]
fn local_rank_panic_mid_collective_wakes_peers() {
    let verdict = finishes_within(60, || {
        dist::run_ranks(4, |comm| {
            if comm.rank() == 2 {
                panic!("injected fault: rank 2");
            }
            // Peers block on the rendezvous; the poison must wake them.
            let _ = comm.exchange_f64(vec![comm.rank() as f64]);
        });
    });
    assert_eq!(verdict, Some(true), "peers must error out, not deadlock");
}

#[test]
fn socket_peer_death_mid_collective_wakes_peers() {
    // Rank 2's sockets close abruptly (no goodbye — process-death
    // semantics) while its peers sit in a collective: every peer must
    // observe the closed connection and panic instead of hanging.
    let verdict = finishes_within(60, || {
        transport::run_ranks_socket(4, |comm| {
            if comm.rank() == 2 {
                comm.sever();
                panic!("injected fault: rank 2 socket closed");
            }
            let _ = comm.exchange_f64(vec![comm.rank() as f64]);
        });
    });
    assert_eq!(verdict, Some(true), "peers must error out, not deadlock");
}

#[test]
fn local_rank_panic_mid_ring_collective_wakes_peers() {
    // Peers sit in p2p mailbox receives (the ring schedule), not the
    // barrier exchange: the poison must wake those too.
    let verdict = finishes_within(60, || {
        dist::run_ranks_algo(4, Algo::Ring, |comm| {
            if comm.rank() == 2 {
                panic!("injected fault: rank 2");
            }
            let m = Mat::from_fn(32, 4, |r, c| (r + c) as f32);
            let _ = collectives::all_reduce_sum(&comm, &[m]);
        });
    });
    assert_eq!(verdict, Some(true), "peers must error out, not deadlock");
}

#[test]
fn socket_peer_death_mid_ring_propagates() {
    // Rank 2's sockets — star and mesh — close abruptly while its peers
    // run a ring all-reduce: every peer must observe the dead link
    // (directly, or transitively when its own neighbor panics and drops
    // out) and fail instead of hanging in the ring.
    let verdict = finishes_within(60, || {
        transport::run_ranks_socket_algo(4, Algo::Ring, |comm| {
            if comm.rank() == 2 {
                comm.sever();
                panic!("injected fault: rank 2 socket closed");
            }
            let m = Mat::from_fn(64, 4, |r, c| (r * 7 + c) as f32);
            let _ = collectives::all_reduce_sum(&comm, &[m]);
        });
    });
    assert_eq!(verdict, Some(true), "peers must error out, not deadlock");
}

#[test]
fn socket_clean_early_exit_is_flagged_as_spmd_violation() {
    // A rank that finishes (goodbye frame) while peers still expect its
    // collective contribution is an SPMD violation: peers must fail.
    let verdict = finishes_within(60, || {
        transport::run_ranks_socket(2, |comm| {
            if comm.rank() == 1 {
                return; // drops the comm: clean goodbye, zero exchanges
            }
            let _ = comm.exchange_f64(vec![0.0]);
        });
    });
    assert_eq!(verdict, Some(true), "early clean exit must fail peers, not deadlock");
}

// =====================================================================
// Nonblocking collectives + chunk-pipelined ring (ISSUE 5): the
// pipelined ring must be bitwise identical to the blocking ring and the
// star on randomized shapes — across transports, world sizes, stage
// counts and overlap modes — and overlapped training must digest
// identically to blocking training. Fault injection: a pending op must
// poison/propagate from wait() (no deadlock), and a PendingOp dropped
// without wait must neither leak nor abort.

/// One rank's pipelined-ring outputs on seeded per-rank random inputs:
/// the auto-staged pipelined all-reduce plus explicit stage counts.
fn pipelined_collectives(
    comm: &dyn Communicator,
    seed: u64,
    shapes: &[(usize, usize)],
) -> Vec<Vec<Mat>> {
    let mut rng = Pcg::with_stream(seed, comm.rank() as u64);
    let mats: Vec<Mat> = shapes.iter().map(|&(r, c)| rng.normal_mat(r, c, 1.0)).collect();
    let mut outs = vec![collectives::all_reduce_sum_pipelined(comm, &mats)];
    for stages in [1usize, 2, 3] {
        outs.push(collectives::all_reduce_sum_pipelined_stages(comm, &mats, stages));
    }
    outs
}

#[test]
fn pipelined_ring_matches_blocking_ring_and_star_across_transports() {
    // Randomized shape lists per (world, trial) including the edges the
    // chunk plan must survive: empty matrices, 1×1 buffers, row counts
    // the plan does not divide, and a multi-chunk payload of ≥ 3·R rows
    // so stage × rank chunking is genuinely exercised.
    let mut shape_rng = Pcg::new(0x9199);
    for world in [2usize, 3, 4] {
        for trial in 0..3 {
            let mut shapes: Vec<(usize, usize)> = (0..1 + shape_rng.below(3))
                .map(|_| (shape_rng.below(7), shape_rng.below(7)))
                .collect();
            shapes.push((1, 1));
            shapes.push((0, 3));
            shapes.push((3 * world + shape_rng.below(5), 2)); // multi-chunk
            let seed = 9100 + (world * 100 + trial) as u64;
            let sh = &shapes;
            // Reference: blocking star, overlap off.
            let star = dist::run_ranks_with(world, Algo::Star, false, |c| {
                let mut rng = Pcg::with_stream(seed, c.rank() as u64);
                let mats: Vec<Mat> =
                    sh.iter().map(|&(r, c2)| rng.normal_mat(r, c2, 1.0)).collect();
                collectives::all_reduce_sum(&c, &mats)
            });
            // Blocking ring, overlap off.
            let ring = dist::run_ranks_with(world, Algo::Ring, false, |c| {
                let mut rng = Pcg::with_stream(seed, c.rank() as u64);
                let mats: Vec<Mat> =
                    sh.iter().map(|&(r, c2)| rng.normal_mat(r, c2, 1.0)).collect();
                collectives::all_reduce_sum(&c, &mats)
            });
            // Pipelined ring, local + socket, auto and explicit stages.
            let pipe_local =
                dist::run_ranks_with(world, Algo::Ring, true, |c| {
                    pipelined_collectives(&c, seed, sh)
                });
            let pipe_socket = transport::run_ranks_socket_with(world, Algo::Ring, true, |c| {
                pipelined_collectives(&c, seed, sh)
            });
            for rank in 0..world {
                let ctx = format!("world {world} trial {trial} rank {rank}");
                assert_mats_bitwise(&star[rank], &ring[rank], &format!("{ctx}: star vs ring"));
                for (variant, outs) in
                    [("pipelined-local", &pipe_local), ("pipelined-socket", &pipe_socket)]
                {
                    for (vi, v) in outs[rank].iter().enumerate() {
                        assert_mats_bitwise(
                            &star[rank],
                            v,
                            &format!("{ctx}: {variant} variant {vi}"),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn overlap_training_digests_match_blocking_bitwise() {
    // The end-to-end overlap-invariance acceptance on the local
    // transport (the socket/process leg lives in rust/tests/dist_proc.rs
    // behind the --overlap axis): overlap ∈ {0,1} × strategy × algo all
    // digest identically to serial.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        for algo in [Algo::Star, Algo::Ring] {
            for overlap in [false, true] {
                let mut dc = DistCfg::local(4, strategy);
                dc.algo = algo;
                dc.overlap = overlap;
                let got = run(&cfg, &ds, Some(&dc));
                assert_bitwise_equal(
                    &serial,
                    &got,
                    &format!("{} {} overlap={}", strategy.name(), algo.name(), overlap),
                );
                assert_eq!(
                    serial.0.param_digest, got.0.param_digest,
                    "{} {} overlap={}: digest",
                    strategy.name(),
                    algo.name(),
                    overlap
                );
            }
        }
    }
}

#[test]
fn local_rank_panic_with_pending_op_in_flight_propagates_from_wait() {
    // Ranks 0/1/3 have a nonblocking all-reduce in flight when rank 2
    // dies: the rendezvous poison must reach the engine job, and wait()
    // must re-raise on the issuing thread — no deadlock.
    let verdict = finishes_within(60, || {
        dist::run_ranks_with(4, Algo::Ring, true, |comm| {
            if comm.rank() == 2 {
                panic!("injected fault: rank 2");
            }
            let m = Mat::from_fn(32, 4, |r, c| (r + c) as f32);
            let op = comm.istart_all_reduce_sum(vec![m]);
            let _ = op.wait();
        });
    });
    assert_eq!(verdict, Some(true), "pending-op peers must error out, not deadlock");
}

#[test]
fn socket_sever_with_pending_op_in_flight_propagates_from_wait() {
    // Same shape over real sockets: rank 2 severs its links while its
    // peers' pending ops are mid-transfer; every peer must observe the
    // dead link (directly or transitively) from wait().
    let verdict = finishes_within(60, || {
        transport::run_ranks_socket_with(4, Algo::Ring, true, |comm| {
            if comm.rank() == 2 {
                comm.sever();
                panic!("injected fault: rank 2 socket closed");
            }
            let m = Mat::from_fn(64, 4, |r, c| (r * 7 + c) as f32);
            let op = comm.istart_all_reduce_sum(vec![m]);
            let _ = op.wait();
        });
    });
    assert_eq!(verdict, Some(true), "pending-op peers must error out, not deadlock");
}

#[test]
fn pending_op_dropped_without_wait_still_completes_and_frees_the_world() {
    // Dropping the handle detaches the op: it must still execute (its
    // peers depend on it — the follow-up blocking exchange would
    // otherwise misalign), the engine must stay usable, and teardown
    // must not leak a blocked progress thread. `finishes_within` is the
    // leak/deadlock watchdog; Some(false) = finished without panicking.
    let verdict = finishes_within(60, || {
        let out = dist::run_ranks_with(3, Algo::Ring, true, |comm| {
            let op = comm.istart_exchange_f64(vec![1.0 + comm.rank() as f64]);
            drop(op); // detach without waiting
            let parts = comm.exchange_f64(vec![10.0 + comm.rank() as f64]);
            parts.iter().map(|p| p[0]).sum::<f64>()
        });
        assert_eq!(out, vec![33.0; 3]);
    });
    assert_eq!(verdict, Some(false), "detached op must neither deadlock nor panic");
}

#[test]
fn socket_comm_drop_drains_pending_ops_before_goodbye() {
    // Every rank issues a pending exchange and returns without waiting:
    // the comm's Drop must drain the op (completing the collective on
    // all ranks) before sending goodbyes — otherwise peers would see an
    // SPMD violation or EOF and the world would panic.
    let verdict = finishes_within(60, || {
        let out = transport::run_ranks_socket_with(2, Algo::Ring, true, |comm| {
            let op = comm.istart_exchange_f64(vec![comm.rank() as f64 + 1.0]);
            drop(op);
            comm.rank()
        });
        assert_eq!(out, vec![0, 1]);
    });
    assert_eq!(verdict, Some(false), "drop-drain must complete cleanly");
}

#[test]
fn ring_all_reduce_per_op_bytes_pin_the_bandwidth_model() {
    // The per-op traffic counters (merged into the global slots at op
    // completion) pin the blocking ring's byte model exactly:
    // 2·(R−1) frames per rank of (header + N/R payload bytes) each —
    // i.e. ~2·(R−1)/R·N payload bytes per rank. Per-op counters are
    // immune to concurrent tests recording on the global slots.
    let world = 4usize;
    let rows = 64usize;
    let cols = 4usize; // N = 256 elems = 1024 B, divisible by world
    let n_bytes = 4 * rows * cols;
    let hdr = 17; // FRAME_HEADER_BYTES (PROTOCOL.md §Framing)
    let want = 2 * (world as u64 - 1) * (hdr + n_bytes as u64 / world as u64);
    let outs = dist::run_ranks_with(world, Algo::Ring, false, |comm| {
        let m = Mat::from_fn(rows, cols, |r, c| (r * cols + c) as f32);
        let op = comm.istart_all_reduce_sum(vec![m]);
        op.join();
        let bytes = op.bytes_sent();
        let _ = op.wait();
        bytes
    });
    for (rank, got) in outs.iter().enumerate() {
        assert_eq!(*got, want, "rank {rank}: blocking-ring bytes off the 2·(R−1)/R·N model");
    }
    // With overlap on, this payload's auto plan is a single stage, so
    // the pipelined schedule puts exactly the same frames on the wire —
    // the per-op counter must agree with the blocking pin bit for bit
    // (the collective runs pipelined inline on the engine thread; its
    // micro-ops are inline there, so all bytes land on this one op).
    let outs = dist::run_ranks_with(world, Algo::Ring, true, |comm| {
        let m = Mat::from_fn(rows, cols, |r, c| (r * cols + c) as f32);
        let op = comm.istart_all_reduce_sum(vec![m]);
        op.join();
        let bytes = op.bytes_sent();
        let _ = op.wait();
        bytes
    });
    for (rank, got) in outs.iter().enumerate() {
        assert_eq!(*got, want, "rank {rank}: single-stage pipelined bytes must match blocking");
    }
}

// =====================================================================
// Shard-planning padding rule in the training driver (ISSUE 3 fix):
// world sizes that do not divide the batch still train — the balanced
// padding rule of shard::row_shard_range replaces the old hard
// divisibility assert. Such runs are deterministic at a fixed world
// size (asserted by a repeat run) and track the serial trajectory to
// rounding (odd shard row counts make the per-shard 1/m scaling
// inexact, so the *bitwise* guarantee rightly stays reserved for
// power-of-two rank counts dividing the batch).

#[test]
fn non_dividing_ranks_train_deterministically_and_track_serial() {
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    let d3a = run(&cfg, &ds, Some(&DistCfg::local(3, DistStrategy::Replicated)));
    let d3b = run(&cfg, &ds, Some(&DistCfg::local(3, DistStrategy::Replicated)));
    // Determinism at fixed world size: two ranks=3 runs are bitwise
    // identical to each other.
    assert_bitwise_equal(&d3a, &d3b, "ranks=3 repeat");
    // Correctness: the curve tracks serial within amplified-rounding
    // slack (ulp-level shard perturbations grow over the 8 steps).
    assert_eq!(serial.0.rows.len(), d3a.0.rows.len());
    for (ra, rb) in serial.0.rows.iter().zip(&d3a.0.rows) {
        assert!(ra.train_loss.is_finite() && rb.train_loss.is_finite());
        assert!(
            (ra.train_loss - rb.train_loss).abs() <= 1e-2 * ra.train_loss.abs().max(1.0),
            "train loss {} vs {}",
            ra.train_loss,
            rb.train_loss
        );
        assert!(
            (ra.test_loss - rb.test_loss).abs() <= 1e-2 * ra.test_loss.abs().max(1.0),
            "test loss {} vs {}",
            ra.test_loss,
            rb.test_loss
        );
    }
    // Parameters: elementwise close to serial.
    assert_eq!(serial.1.len(), d3a.1.len());
    for (l, (pa, pb)) in serial.1.iter().zip(&d3a.1).enumerate() {
        for (x, y) in pa.data().iter().zip(pb.data()) {
            assert!((x - y).abs() <= 1e-2 * x.abs().max(1.0), "layer {l}: {x} vs {y}");
        }
    }
}

// =====================================================================
// Checkpoint/resume determinism (elastic fault tolerance, ISSUE 6):
// train N steps → checkpoint → resume M more must be bitwise identical
// to the uninterrupted N+M run — rows, params and digest. The resumed
// driver replays the skipped steps' batch draws without touching the
// model and restores the f64 epoch partials at the boundary, so even
// the re-emitted row of the interrupted epoch matches bit for bit.

fn resume_tmp(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("singd-resume-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create resume temp dir");
    dir
}

/// Checkpoint at step 4 of a 1-epoch run (the fixture has 4 steps per
/// epoch), then resume the full 2-epoch schedule from it; the result
/// must be bitwise identical to the uninterrupted 2-epoch run.
fn assert_resume_matches(
    cfg: &TrainCfg,
    ds: &singd::data::Dataset,
    dc: Option<&DistCfg>,
    tag: &str,
) {
    let dir = resume_tmp(tag);
    let ckpt = dir.join("run.ckpt");
    let full = run(cfg, ds, dc);
    let mut c1 = cfg.clone();
    c1.epochs = 1;
    c1.ckpt = Some(ckpt.clone());
    c1.ckpt_every = 4;
    let _ = run(&c1, ds, dc);
    assert!(ckpt.exists(), "{tag}: checkpoint not written");
    let mut c2 = cfg.clone();
    c2.resume = Some(ckpt);
    let resumed = run(&c2, ds, dc);
    assert_bitwise_equal(&full, &resumed, tag);
    assert_eq!(full.0.param_digest, resumed.0.param_digest, "{tag}: digest");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_is_bitwise_identical_serial_singd() {
    let (ds, cfg) = fixture();
    assert_resume_matches(&cfg, &ds, None, "serial-singd");
}

#[test]
fn resume_is_bitwise_identical_local_singd() {
    let (ds, cfg) = fixture();
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let dc = DistCfg::local(4, strategy);
        assert_resume_matches(&cfg, &ds, Some(&dc), &format!("local-singd-{}", strategy.name()));
    }
}

#[test]
fn resume_is_bitwise_identical_local_kfac() {
    let (ds, mut cfg) = fixture();
    cfg.method = Method::Kfac;
    cfg.hyper = Hyper { lr: 0.01, damping: 0.1, t_update: 1, update_clip: 0.05, ..Hyper::default() };
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let dc = DistCfg::local(4, strategy);
        assert_resume_matches(&cfg, &ds, Some(&dc), &format!("local-kfac-{}", strategy.name()));
    }
}

#[test]
fn resume_across_worlds_reshards_state_bitwise() {
    // The resharding determinism contract (ARCHITECTURE.md): checkpoints
    // hold *canonical* (world-agnostic) optimizer state, so a checkpoint
    // written under ranks=4 factor-sharded resumes under ranks=2 — and
    // the result is bitwise identical to the uninterrupted ranks=2 run.
    let (ds, cfg) = fixture();
    let dir = resume_tmp("reshard");
    let ckpt = dir.join("run.ckpt");
    let full2 = run(&cfg, &ds, Some(&DistCfg::local(2, DistStrategy::FactorSharded)));
    let mut c1 = cfg.clone();
    c1.epochs = 1;
    c1.ckpt = Some(ckpt.clone());
    c1.ckpt_every = 4;
    let _ = run(&c1, &ds, Some(&DistCfg::local(4, DistStrategy::FactorSharded)));
    assert!(ckpt.exists(), "reshard: checkpoint not written");
    let mut c2 = cfg.clone();
    c2.resume = Some(ckpt);
    let resumed = run(&c2, &ds, Some(&DistCfg::local(2, DistStrategy::FactorSharded)));
    assert_bitwise_equal(&full2, &resumed, "reshard 4→2");
    assert_eq!(full2.0.param_digest, resumed.0.param_digest, "reshard 4→2: digest");
    std::fs::remove_dir_all(&dir).ok();
}

// =====================================================================
// Elastic rendezvous v2 (in-process component tests; the multi-process
// chaos leg — a real OS worker killed mid-step — lives in
// rust/tests/dist_proc.rs). These exercise the coordinator, the
// membership regroup, fresh joins and the per-generation data plane
// over real Unix sockets inside this process, under the deadlock
// watchdog.

#[test]
fn elastic_regroup_after_death_shrinks_world() {
    // World 4, generation 0: rank 2 dies abruptly (severed sockets, no
    // goodbye). Survivors observe EOF mid-collective, sever their own
    // links (cascading the failure), regroup into generation 1 as world
    // 3 with ranks reassigned by old-rank order, and the new data plane
    // must work.
    let verdict = finishes_within(120, || {
        let rendezvous = transport::fresh_rendezvous();
        let run_id = transport::fresh_run_id();
        let rv = &rendezvous;
        let outs: Vec<Option<(usize, transport::Membership, f64)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4usize)
                .map(|r| {
                    s.spawn(move || {
                        let coord = (r == 0).then(|| {
                            transport::Coordinator::new(rv, run_id, 4).expect("coordinator")
                        });
                        let comm = transport::SocketComm::connect_elastic(
                            r, 4, rv, run_id, 0, Algo::Star, false, Dtype::F32,
                        )
                        .expect("gen-0 connect");
                        let gen0 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            if r == 2 {
                                comm.sever();
                                panic!("injected fault: rank 2 dies");
                            }
                            let _ = comm.exchange_f64(vec![r as f64]);
                        }));
                        assert!(gen0.is_err(), "rank {r}: must observe the dead peer");
                        comm.sever(); // cascade the failure, as the driver does
                        drop(comm);
                        if r == 2 {
                            return None; // dead: never rejoins
                        }
                        let m = match &coord {
                            Some(c) => c.regroup(1).expect("regroup"),
                            None => transport::rejoin(rv, run_id, r, 1).expect("rejoin"),
                        };
                        let comm = transport::SocketComm::connect_elastic(
                            m.rank, m.world, rv, run_id, 1, Algo::Star, false, Dtype::F32,
                        )
                        .expect("gen-1 connect");
                        let parts = comm.exchange_f64(vec![m.rank as f64]);
                        let sum: f64 = parts.iter().map(|p| p[0]).sum();
                        if let Some(c) = &coord {
                            c.finish();
                        }
                        Some((r, m, sum))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(outs[2], None, "rank 2 is dead");
        // Survivors keep old-rank order: 0→0, 1→1, 3→2; world 3.
        for (old, new) in [(0usize, 0usize), (1, 1), (3, 2)] {
            let (r, m, sum) = outs[old].expect("survivor result");
            assert_eq!(r, old);
            assert_eq!(m, transport::Membership { rank: new, world: 3, gen: 1 });
            assert_eq!(sum, 3.0, "gen-1 exchange sum (0+1+2)");
        }
    });
    assert_eq!(verdict, Some(false), "regroup must complete cleanly, not deadlock");
}

#[test]
fn elastic_join_grows_world_and_status_tracks_it() {
    // World 2, generation 0: a fresh worker parks a join request at the
    // control endpoint. Rank 0 folds its pending-join flag into the
    // per-step scalar exchange (exactly as the elastic driver does), the
    // members leave generation 0 cleanly, regroup admits the joiner as
    // rank 2 of world 3, and `/status` tracks the epoch throughout.
    let verdict = finishes_within(120, || {
        let rendezvous = transport::fresh_rendezvous();
        let run_id = transport::fresh_run_id();
        let rv = &rendezvous;
        std::thread::scope(|s| {
            let joiner = s.spawn(move || {
                let m = transport::join(rv, run_id).expect("join");
                let comm = transport::SocketComm::connect_elastic(
                    m.rank, m.world, rv, run_id, m.gen, Algo::Star, false, Dtype::F32,
                )
                .expect("joiner gen-1 connect");
                let parts = comm.exchange_f64(vec![m.rank as f64]);
                (m, parts.iter().map(|p| p[0]).sum::<f64>())
            });
            let members: Vec<_> = (0..2usize)
                .map(|r| {
                    s.spawn(move || {
                        let coord = (r == 0).then(|| {
                            transport::Coordinator::new(rv, run_id, 2).expect("coordinator")
                        });
                        let comm = transport::SocketComm::connect_elastic(
                            r, 2, rv, run_id, 0, Algo::Star, false, Dtype::F32,
                        )
                        .expect("gen-0 connect");
                        // Per-step pending-join poll, driver-style: every
                        // rank learns of the joiner on the same step.
                        loop {
                            let flag = match &coord {
                                Some(c) if c.join_pending() => 1.0,
                                _ => 0.0,
                            };
                            let parts = comm.exchange_f64(vec![flag]);
                            if parts.iter().any(|p| p[0] != 0.0) {
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        drop(comm); // leave generation 0 cleanly
                        let m = match &coord {
                            Some(c) => {
                                let st = transport::status(rv, run_id).expect("status gen-0");
                                assert_eq!((st.world, st.gen), (2, 0));
                                c.regroup(1).expect("regroup")
                            }
                            None => transport::rejoin(rv, run_id, r, 1).expect("rejoin"),
                        };
                        let comm = transport::SocketComm::connect_elastic(
                            m.rank, m.world, rv, run_id, 1, Algo::Star, false, Dtype::F32,
                        )
                        .expect("gen-1 connect");
                        let parts = comm.exchange_f64(vec![m.rank as f64]);
                        let sum: f64 = parts.iter().map(|p| p[0]).sum();
                        if let Some(c) = &coord {
                            let st = transport::status(rv, run_id).expect("status gen-1");
                            assert_eq!((st.world, st.gen), (3, 1));
                            c.finish();
                            let st = transport::status(rv, run_id).expect("status done");
                            assert_eq!(st.state, transport::RunState::Done);
                        }
                        (m, sum)
                    })
                })
                .collect();
            let (jm, jsum) = joiner.join().unwrap();
            assert_eq!(jm, transport::Membership { rank: 2, world: 3, gen: 1 });
            assert_eq!(jsum, 3.0, "joiner gen-1 exchange sum");
            for (r, h) in members.into_iter().enumerate() {
                let (m, sum) = h.join().unwrap();
                assert_eq!(m, transport::Membership { rank: r, world: 3, gen: 1 });
                assert_eq!(sum, 3.0, "member {r} gen-1 exchange sum");
            }
        });
    });
    assert_eq!(verdict, Some(false), "join/regroup must complete cleanly, not deadlock");
}

// =====================================================================
// Observability non-interference (PR 7): tracing must never change the
// training math. The trace session is process-global, so every traced
// test in this binary serializes on `trace_lock` — an untraced sibling
// running concurrently only ever sees cheap inert hooks.

fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    L.get_or_init(|| std::sync::Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn fresh_trace_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("singd-dist-trace-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn tracing_is_bitwise_noninterfering_across_algo_and_overlap() {
    // The sixth contract: for every (algo, overlap) cell, a traced run
    // digests bitwise identically to the untraced run — spans observe
    // the step, they never participate in it.
    let _g = trace_lock();
    let (ds, cfg) = fixture();
    for algo in [Algo::Star, Algo::Ring] {
        for overlap in [false, true] {
            let dc = DistCfg {
                ranks: 4,
                strategy: DistStrategy::FactorSharded,
                transport: Transport::Local,
                algo,
                overlap,
                stream: dist::default_stream(),
                wire_dtype: Dtype::F32,
                elastic: false,
            };
            let plain = run(&cfg, &ds, Some(&dc));
            let dir = fresh_trace_dir(&format!("ni-{}-{overlap}", algo.name()));
            let mut traced_cfg = cfg.clone();
            traced_cfg.trace_dir = Some(dir.clone());
            let traced = run(&traced_cfg, &ds, Some(&dc));
            let ctx = format!("algo={} overlap={overlap}", algo.name());
            assert_bitwise_equal(&plain, &traced, &format!("traced vs untraced ({ctx})"));
            assert_eq!(
                plain.0.param_digest, traced.0.param_digest,
                "{ctx}: digest changed with tracing on"
            );
            // Every rank of the local world exports its artifacts.
            for r in 0..4 {
                assert!(
                    dir.join(format!("r{r}.jsonl")).exists(),
                    "{ctx}: missing r{r}.jsonl in {}",
                    dir.display()
                );
                assert!(
                    dir.join(format!("r{r}.trace.json")).exists(),
                    "{ctx}: missing r{r}.trace.json"
                );
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn trace_span_files_are_well_formed_and_phases_nest() {
    // One traced run; then structural checks on the artifacts: the
    // journal is one JSON object per line with the required keys, the
    // Chrome file is a loadable traceEvents wrapper, and every step
    // phase recorded by `rank_step` nests inside a `step` span.
    let _g = trace_lock();
    let (ds, cfg) = fixture();
    let dir = fresh_trace_dir("wellformed");
    let mut cfg = cfg;
    cfg.trace_dir = Some(dir.clone());
    let dc = DistCfg {
        ranks: 2,
        strategy: DistStrategy::Replicated,
        transport: Transport::Local,
        algo: Algo::Ring,
        overlap: true,
        stream: dist::default_stream(),
        wire_dtype: Dtype::F32,
        elastic: false,
    };
    let (res, _) = run(&cfg, &ds, Some(&dc));
    assert!(!res.diverged);
    // `step` spans live on the driver thread (session default rank 0);
    // rank_step phases live on the worker ranks. All share the session
    // clock, so phase intervals must nest inside some step interval.
    let mut steps: Vec<(u64, u64)> = Vec::new();
    let mut phases: Vec<(u32, String, u64, u64)> = Vec::new();
    for r in 0..2u32 {
        let jsonl = std::fs::read_to_string(dir.join(format!("r{r}.jsonl")))
            .unwrap_or_else(|e| panic!("r{r}.jsonl: {e}"));
        assert!(!jsonl.trim().is_empty(), "r{r}.jsonl is empty");
        let mut saw_fb = false;
        for line in jsonl.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "bad journal line {line:?}");
            for key in ["\"name\":", "\"cat\":", "\"ph\":", "\"ts_us\":", "\"dur_us\":", "\"args\":"]
            {
                assert!(line.contains(key), "journal line missing {key}: {line}");
            }
            let field = |k: &str| -> Option<u64> {
                let tail = &line[line.find(k)? + k.len()..];
                let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
                digits.parse().ok()
            };
            let name_of = |l: &str| -> String {
                let tail = &l[l.find("\"name\":\"").unwrap() + 8..];
                tail[..tail.find('"').unwrap()].to_string()
            };
            assert_eq!(field("\"rank\":"), Some(r as u64), "event on a foreign rank: {line}");
            let (ts, dur) = (field("\"ts_us\":").unwrap(), field("\"dur_us\":").unwrap());
            let name = name_of(line);
            if name == "step" {
                steps.push((ts, ts + dur));
            } else if ["forward_backward", "grad_reconstruct", "precond_update"]
                .contains(&name.as_str())
            {
                saw_fb |= name == "forward_backward";
                phases.push((r, name, ts, ts + dur));
            }
        }
        assert!(saw_fb, "r{r}: no forward_backward phase");
        let chrome = std::fs::read_to_string(dir.join(format!("r{r}.trace.json"))).unwrap();
        assert!(chrome.starts_with("{\"traceEvents\":["), "chrome header");
        assert!(chrome.trim_end().ends_with("]}"), "chrome footer");
    }
    assert!(!steps.is_empty(), "no step spans recorded");
    // Concurrent tests in this binary also record into the armed session
    // (it is process-global), and a step that was already in flight when
    // the session armed legitimately leaves orphan phases — so require
    // nesting per phase kind, not for every instance. The exhaustive
    // every-phase check runs against a pristine single-job process in
    // rust/tests/dist_proc.rs.
    for kind in ["forward_backward", "grad_reconstruct", "precond_update"] {
        assert!(
            phases
                .iter()
                .filter(|(_, n, _, _)| n == kind)
                .any(|(_, _, a, b)| steps.iter().any(|(sa, sb)| sa <= a && b <= sb)),
            "no {kind} phase nests inside any step span {steps:?}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// =====================================================================
// Wire-dtype compressed collectives (ISSUE 8 tentpole). At a fixed wire
// dtype the determinism contract is refined to *bitwise within a wire
// dtype*: every collective must produce identical bits across
// transport ∈ {local, socket} × algo ∈ {star, ring} × overlap ∈ {off,
// on} — while half wire dtypes move 2-byte payloads and therefore
// forfeit the serial-equality guarantee. ci.sh drives these cells (and
// only these: the f32-pinned suites above are *not* wire-invariant)
// under SINGD_WIRE_DTYPE ∈ {f32, bf16} on both transports.

/// One rank's outputs from every wire-dispatched bulk collective on
/// seeded per-rank random inputs (mixed shapes incl. 1×1 and 0-row).
#[allow(clippy::type_complexity)]
fn wire_collectives(comm: &dyn Communicator, seed: u64) -> (Vec<Mat>, Vec<Mat>, Mat, Mat, Vec<Mat>) {
    let mut rng = Pcg::with_stream(seed, comm.rank() as u64);
    let mats: Vec<Mat> =
        vec![rng.normal_mat(5, 3, 1.0), rng.normal_mat(1, 1, 1.0), Mat::zeros(0, 4)];
    let reduced = collectives::all_reduce_sum(comm, &mats);
    let mut bucketed = mats.clone();
    bucket::all_reduce_sum_bucketed(comm, &mut bucketed, 7);
    let tall = rng.normal_mat(7, 2, 1.0);
    let gathered = collectives::all_gather_rows(comm, &tall);
    let scattered = collectives::reduce_scatter_rows(comm, &tall);
    let root = 1 % comm.world_size();
    let payload = if comm.rank() == root { mats.clone() } else { Vec::new() };
    let bcast = collectives::broadcast(comm, root, payload);
    (reduced, bucketed, gathered, scattered, bcast)
}

#[test]
fn wire_collectives_bitwise_invariant_across_transport_algo_overlap() {
    let world = 4usize;
    let seed = 0x317e;
    for wire in [Dtype::Bf16, Dtype::Fp16] {
        let base =
            dist::run_ranks_wire(world, Algo::Star, false, wire, |c| wire_collectives(&c, seed));
        let variants: Vec<(&str, Vec<_>)> = vec![
            (
                "local-ring",
                dist::run_ranks_wire(world, Algo::Ring, false, wire, |c| {
                    wire_collectives(&c, seed)
                }),
            ),
            (
                "local-ring-overlap",
                dist::run_ranks_wire(world, Algo::Ring, true, wire, |c| {
                    wire_collectives(&c, seed)
                }),
            ),
            (
                "socket-star",
                transport::run_ranks_socket_wire(world, Algo::Star, false, wire, |c| {
                    wire_collectives(&c, seed)
                }),
            ),
            (
                "socket-ring-overlap",
                transport::run_ranks_socket_wire(world, Algo::Ring, true, wire, |c| {
                    wire_collectives(&c, seed)
                }),
            ),
        ];
        for (name, variant) in &variants {
            for (rank, (a, b)) in base.iter().zip(variant.iter()).enumerate() {
                let ctx = format!("wire {} rank {rank} star-local vs {name}", wire.name());
                assert_mats_bitwise(&a.0, &b.0, &format!("{ctx}: all_reduce"));
                assert_mats_bitwise(&a.1, &b.1, &format!("{ctx}: bucketed all_reduce"));
                assert_mats_bitwise(
                    std::slice::from_ref(&a.2),
                    std::slice::from_ref(&b.2),
                    &format!("{ctx}: all_gather_rows"),
                );
                assert_mats_bitwise(
                    std::slice::from_ref(&a.3),
                    std::slice::from_ref(&b.3),
                    &format!("{ctx}: reduce_scatter_rows"),
                );
                assert_mats_bitwise(&a.4, &b.4, &format!("{ctx}: broadcast"));
            }
        }
    }
}

#[test]
fn wire_ring_all_reduce_bytes_pin_the_compressed_bandwidth_model() {
    // The per-op traffic counters must be dtype-sized: a half wire dtype
    // halves every chunk payload, so the blocking ring's byte model
    // becomes 2·(R−1) frames of (header + N·w/R bytes) with w the wire
    // element width — ~2× less bulk payload than the f32 wire.
    let world = 4usize;
    let rows = 64usize;
    let cols = 4usize; // N = 256 elems, divisible by world
    let elems = (rows * cols) as u64;
    let hdr = 17u64; // FRAME_HEADER_BYTES (PROTOCOL.md §Framing)
    for wire in [Dtype::F32, Dtype::Bf16, Dtype::Fp16] {
        let want = 2 * (world as u64 - 1) * (hdr + elems * wire.bytes() as u64 / world as u64);
        let outs = dist::run_ranks_wire(world, Algo::Ring, false, wire, |comm| {
            let m = Mat::from_fn(rows, cols, |r, c| (r * cols + c) as f32);
            let op = comm.istart_all_reduce_sum(vec![m]);
            op.join();
            let bytes = op.bytes_sent();
            let _ = op.wait();
            bytes
        });
        for (rank, got) in outs.iter().enumerate() {
            assert_eq!(
                *got,
                want,
                "rank {rank} wire {}: ring bytes off the dtype-sized model",
                wire.name()
            );
        }
    }
}

#[test]
fn wire_training_digests_bitwise_invariant_across_algo_and_overlap() {
    // End-to-end: the same fixture trained at a bf16 wire digests
    // bitwise identically across algo × overlap (serial equality is
    // void at a half wire — the invariance is dist-vs-dist).
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let mut outs = Vec::new();
    for algo in [Algo::Star, Algo::Ring] {
        for overlap in [false, true] {
            let dc = DistCfg {
                ranks: 4,
                strategy: DistStrategy::FactorSharded,
                transport: Transport::Local,
                algo,
                overlap,
                stream: dist::default_stream(),
                wire_dtype: Dtype::Bf16,
                elastic: false,
            };
            outs.push((format!("{} overlap={overlap}", algo.name()), run(&cfg, &ds, Some(&dc))));
        }
    }
    let (base_name, base) = &outs[0];
    for (name, out) in &outs[1..] {
        assert_bitwise_equal(base, out, &format!("bf16 wire: {base_name} vs {name}"));
        assert_eq!(
            base.0.param_digest, out.0.param_digest,
            "bf16 wire digest: {base_name} vs {name}"
        );
    }
}

#[test]
fn wire_fp16_store_resume_is_bitwise_identical_with_scaler_state() {
    // fp16 storage arms the GradScaler, whose loss-scale schedule is
    // live state: checkpoint v4 persists it, and resuming mid-schedule
    // must be bitwise identical to the uninterrupted run — serial and
    // distributed (the distributed leg inherits the ambient
    // SINGD_WIRE_DTYPE via DistCfg::local, so the ci.sh wire cells also
    // drive it through the compressed collectives).
    let (ds, mut cfg) = fixture();
    cfg.hyper.policy = singd::numerics::Policy::fp16_mixed();
    assert_resume_matches(&cfg, &ds, None, "fp16-serial");
    let dc = DistCfg::local(4, DistStrategy::Replicated);
    assert_resume_matches(&cfg, &ds, Some(&dc), "fp16-local");
}

// =====================================================================
// Layer-streamed backward↔comm fusion (ISSUE 9 tentpole). Determinism
// contract 8 (stream invariance, ARCHITECTURE.md): issuing each layer's
// statistics gather from *inside* its backward hook moves only the
// op's issue time — reverse layer order, SPMD-consistent on every rank,
// same bytes through the same FIFO engine — so stream on == stream off
// == serial, bit for bit. These are the `stream_` conformance cells
// ci.sh drives under SINGD_STREAM ∈ {0, 1}; the socket-transport and
// real-OS-process legs of the axis live in rust/tests/dist_proc.rs
// (a test binary cannot re-exec itself as workers).

#[test]
fn stream_training_matches_serial_and_unstreamed_bitwise() {
    // The headline grid: R ∈ {1, 2, 4} × strategy × algo, streaming on
    // vs off (both overlapped) vs serial — losses, params and digests
    // all bitwise.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    for ranks in [1usize, 2, 4] {
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            for algo in [Algo::Star, Algo::Ring] {
                let mut on = DistCfg::local(ranks, strategy);
                on.algo = algo;
                on.overlap = true;
                on.stream = true;
                let mut off = on.clone();
                off.stream = false;
                let run_on = run(&cfg, &ds, Some(&on));
                let run_off = run(&cfg, &ds, Some(&off));
                let ctx = format!("ranks={ranks} {} {}", strategy.name(), algo.name());
                assert_bitwise_equal(&serial, &run_on, &format!("{ctx}: stream on vs serial"));
                assert_bitwise_equal(&run_on, &run_off, &format!("{ctx}: stream on vs off"));
                assert_eq!(
                    run_on.0.param_digest, run_off.0.param_digest,
                    "{ctx}: stream digest"
                );
            }
        }
    }
}

#[test]
fn stream_without_overlap_is_inert() {
    // Streaming rides the pending-op engine, so it requires overlap;
    // with overlap off the knob must be a no-op — identical bits either
    // way, still serial-equal (the blocking batched-gather path).
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    for stream in [false, true] {
        let mut dc = DistCfg::local(4, DistStrategy::FactorSharded);
        dc.overlap = false;
        dc.stream = stream;
        let out = run(&cfg, &ds, Some(&dc));
        assert_bitwise_equal(&serial, &out, &format!("overlap=0 stream={stream}"));
    }
}

#[test]
fn stream_kfac_training_matches_serial_bitwise() {
    // The second optimizer family through the hook seam: KFAC's stats
    // consume the identical gathered rows, so the contract carries over.
    let (ds, mut cfg) = fixture();
    cfg.method = Method::Kfac;
    cfg.hyper = Hyper { lr: 0.01, damping: 0.1, t_update: 1, update_clip: 0.05, ..Hyper::default() };
    cfg.epochs = 1;
    let serial = run(&cfg, &ds, None);
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        for stream in [false, true] {
            let mut dc = DistCfg::local(4, strategy);
            dc.algo = Algo::Ring;
            dc.overlap = true;
            dc.stream = stream;
            let out = run(&cfg, &ds, Some(&dc));
            assert_bitwise_equal(
                &serial,
                &out,
                &format!("kfac {} stream={stream}", strategy.name()),
            );
        }
    }
}

#[test]
fn stream_trace_records_layer_gather_issue_inside_forward_backward() {
    // Trace-backed overlap regression: with streaming on, every
    // `layer_gather_issue` span must nest inside a `forward_backward`
    // span on the same rank — the gather demonstrably launches while
    // that rank's backward is still running. (The converse — no such
    // spans with streaming off — needs a pristine process because the
    // trace session is process-global and sibling tests stream by
    // default; rust/tests/dist_proc.rs pins it.)
    let _g = trace_lock();
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let dir = fresh_trace_dir("stream-issue");
    cfg.trace_dir = Some(dir.clone());
    let mut dc = DistCfg::local(2, DistStrategy::Replicated);
    dc.algo = Algo::Ring;
    dc.overlap = true;
    dc.stream = true;
    let (res, _) = run(&cfg, &ds, Some(&dc));
    assert!(!res.diverged);
    let mut issues = 0usize;
    for r in 0..2u64 {
        let jsonl = std::fs::read_to_string(dir.join(format!("r{r}.jsonl")))
            .unwrap_or_else(|e| panic!("r{r}.jsonl: {e}"));
        let mut fb: Vec<(u64, u64)> = Vec::new();
        let mut gi: Vec<(u64, u64)> = Vec::new();
        for line in jsonl.lines() {
            let field = |k: &str| -> u64 {
                let tail =
                    &line[line.find(k).unwrap_or_else(|| panic!("no {k} in {line}")) + k.len()..];
                let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
                digits.parse().unwrap_or_else(|e| panic!("bad {k} in {line}: {e}"))
            };
            if line.contains("\"name\":\"forward_backward\"") {
                fb.push((field("\"ts_us\":"), field("\"ts_us\":") + field("\"dur_us\":")));
            } else if line.contains("\"name\":\"layer_gather_issue\"") {
                gi.push((field("\"ts_us\":"), field("\"ts_us\":") + field("\"dur_us\":")));
            }
        }
        assert!(!fb.is_empty(), "r{r}: no forward_backward spans");
        // Sibling tests recording into the armed session can leave
        // orphan issue spans whose enclosing backward predates the
        // session (see trace_span_files_are_well_formed_and_phases_nest)
        // — so require nesting for the spans this run owns: at least one
        // per rank, rather than every instance unconditionally.
        let nested =
            gi.iter().filter(|(a, b)| fb.iter().any(|(fa, fe)| fa <= a && b <= fe)).count();
        assert!(
            nested >= 1,
            "r{r}: no layer_gather_issue span nests in any forward_backward span \
             (issues: {gi:?}, backwards: {fb:?})"
        );
        issues += nested;
    }
    // 2 ranks × 4 layers × 4 steps of streamed gathers were issued here.
    assert!(issues >= 8, "too few nested layer_gather_issue spans: {issues}");
    let _ = std::fs::remove_dir_all(&dir);
}

// =====================================================================
// Gradient accumulation (ISSUE 9 satellite): k micro-batches of B/k
// rows fold into the full-batch statistics bitwise when every micro
// height is a power of two (the per-micro 1/m softmax scale is an exact
// exponent shift; stats rows concatenate exactly; f64 loss partials are
// complete halving-tree subtrees). The randomized shape/count property
// tests live in rust/src/optim/accum.rs; these cells pin the driver
// integration serial × dist × stream.

#[test]
fn accum_micro_batches_match_unsplit_run_bitwise_serial_and_dist() {
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let base = run(&cfg, &ds, None);
    for k in [2usize, 4] {
        let mut acc_cfg = cfg.clone();
        acc_cfg.accum_steps = k;
        // Serial: 32-row batches → 16- and 8-row micros (powers of two).
        let serial_acc = run(&acc_cfg, &ds, None);
        assert_bitwise_equal(&base, &serial_acc, &format!("serial accum k={k}"));
        // Dist: 8-row rank shards → 4- and 2-row micros; the last micro
        // streams its gathers from inside the backward when stream is on.
        for ranks in [1usize, 4] {
            for stream in [false, true] {
                let mut dc = DistCfg::local(ranks, DistStrategy::FactorSharded);
                dc.overlap = true;
                dc.stream = stream;
                let out = run(&acc_cfg, &ds, Some(&dc));
                assert_bitwise_equal(
                    &base,
                    &out,
                    &format!("accum k={k} ranks={ranks} stream={stream}"),
                );
            }
        }
    }
}

#[test]
fn accum_non_dividing_micro_split_stays_deterministic() {
    // k = 3 on 32-row batches → 11/11/10-row micros via row_shard_range:
    // non-power-of-two heights forfeit the bitwise guarantee (the 1/m
    // softmax scale is no longer an exponent shift) but the split is
    // still a pure function of (rows, k), so repeated runs must agree
    // bit for bit — serial and distributed.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    cfg.accum_steps = 3;
    let a = run(&cfg, &ds, None);
    let b = run(&cfg, &ds, None);
    assert_bitwise_equal(&a, &b, "serial accum k=3 repeat");
    let dc = DistCfg::local(4, DistStrategy::FactorSharded);
    let da = run(&cfg, &ds, Some(&dc));
    let db = run(&cfg, &ds, Some(&dc));
    assert_bitwise_equal(&da, &db, "dist accum k=3 repeat");
}

#[test]
fn accum_fp16_scaler_overflow_schedule_stays_in_lockstep() {
    // fp16 storage arms the GradScaler, whose overflow-skip schedule is
    // live cross-step state: accumulation must leave it bitwise
    // untouched — the split run sees the identical reconstructed
    // gradients, so it skips exactly the steps the unsplit run skips.
    // Checked serial and at ranks=4 (where the overflow verdict is
    // OR-reduced across ranks before any state moves).
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    cfg.hyper.policy = singd::numerics::Policy::fp16_mixed();
    let mut split_cfg = cfg.clone();
    split_cfg.accum_steps = 2;
    let serial = run(&cfg, &ds, None);
    let serial_split = run(&split_cfg, &ds, None);
    assert_bitwise_equal(&serial, &serial_split, "fp16 serial accum k=2");
    let dc = DistCfg::local(4, DistStrategy::Replicated);
    let dist = run(&cfg, &ds, Some(&dc));
    let dist_split = run(&split_cfg, &ds, Some(&dc));
    assert_bitwise_equal(&dist, &dist_split, "fp16 ranks=4 accum k=2");
    assert_eq!(
        dist.0.param_digest, dist_split.0.param_digest,
        "fp16 ranks=4 accum digest"
    );
}

// =====================================================================
// Optimizer zoo (RK-FAC sketched + MAC): the two cheap-curvature
// optimizers behind the same sharded trait must uphold the same
// determinism grid as SINGD/KFAC — rank invariance under both
// strategies, algo and stream invariance, checkpoint-resume, and
// cross-world resharding. The socket-transport and real-OS-process legs
// of this axis live in rust/tests/dist_proc.rs (a test binary cannot
// re-exec itself as workers).

/// The zoo methods with the hypers their unit suites converge under
/// (both need the heavy second-order damping — their sketch/rank-1
/// curvature null spaces are amplified by 1/λ).
fn zoo_cfgs() -> Vec<(Method, Hyper)> {
    vec![
        (
            Method::RkFac { k: 4 },
            Hyper { lr: 0.01, damping: 0.1, t_update: 1, update_clip: 0.05, ..Hyper::default() },
        ),
        (Method::Mac, Hyper { lr: 0.01, damping: 0.1, t_update: 1, ..Hyper::default() }),
    ]
}

#[test]
fn zoo_rank_invariance_replicated_and_factor_sharded() {
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    for (method, hp) in zoo_cfgs() {
        cfg.method = method.clone();
        cfg.hyper = hp;
        let name = method.name();
        let serial = run(&cfg, &ds, None);
        let d1 = run(&cfg, &ds, Some(&DistCfg::local(1, DistStrategy::Replicated)));
        assert_bitwise_equal(&serial, &d1, &format!("{name} serial vs ranks=1"));
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            let d4 = run(&cfg, &ds, Some(&DistCfg::local(4, strategy)));
            assert_bitwise_equal(&d1, &d4, &format!("{name} ranks=4 {}", strategy.name()));
        }
    }
}

#[test]
fn zoo_stream_and_algo_grid_matches_serial_bitwise() {
    // Method × strategy × algo × stream ∈ {0,1}, all overlapped, at
    // ranks=4 — every cell bitwise equal to the serial run.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    for (method, hp) in zoo_cfgs() {
        cfg.method = method.clone();
        cfg.hyper = hp;
        let name = method.name();
        let serial = run(&cfg, &ds, None);
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            for algo in [Algo::Star, Algo::Ring] {
                for stream in [false, true] {
                    let mut dc = DistCfg::local(4, strategy);
                    dc.algo = algo;
                    dc.overlap = true;
                    dc.stream = stream;
                    let out = run(&cfg, &ds, Some(&dc));
                    assert_bitwise_equal(
                        &serial,
                        &out,
                        &format!(
                            "{name} {} {} stream={stream}",
                            strategy.name(),
                            algo.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn zoo_resume_is_bitwise_identical() {
    let (ds, mut cfg) = fixture();
    for (method, hp) in zoo_cfgs() {
        cfg.method = method.clone();
        cfg.hyper = hp;
        let name = method.name().replace(':', "_");
        assert_resume_matches(&cfg, &ds, None, &format!("serial-{name}"));
        for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
            let dc = DistCfg::local(4, strategy);
            assert_resume_matches(
                &cfg,
                &ds,
                Some(&dc),
                &format!("local-{name}-{}", strategy.name()),
            );
        }
    }
}

#[test]
fn zoo_resume_across_worlds_reshards_state_bitwise() {
    // The elastic reshard cell per new optimizer: a ranks=4
    // factor-sharded checkpoint (canonical state) resumes under ranks=2
    // factor-sharded, bitwise equal to the uninterrupted ranks=2 run.
    let (ds, mut cfg) = fixture();
    for (method, hp) in zoo_cfgs() {
        cfg.method = method.clone();
        cfg.hyper = hp;
        let name = method.name().replace(':', "_");
        let dir = resume_tmp(&format!("reshard-{name}"));
        let ckpt = dir.join("run.ckpt");
        let full2 = run(&cfg, &ds, Some(&DistCfg::local(2, DistStrategy::FactorSharded)));
        let mut c1 = cfg.clone();
        c1.epochs = 1;
        c1.ckpt = Some(ckpt.clone());
        c1.ckpt_every = 4;
        let _ = run(&c1, &ds, Some(&DistCfg::local(4, DistStrategy::FactorSharded)));
        assert!(ckpt.exists(), "{name} reshard: checkpoint not written");
        let mut c2 = cfg.clone();
        c2.resume = Some(ckpt);
        let resumed = run(&c2, &ds, Some(&DistCfg::local(2, DistStrategy::FactorSharded)));
        assert_bitwise_equal(&full2, &resumed, &format!("{name} reshard 4→2"));
        assert_eq!(
            full2.0.param_digest, resumed.0.param_digest,
            "{name} reshard 4→2: digest"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn zoo_factor_sharded_per_rank_state_shrinks() {
    // Memory claim behind the sharding: under factor sharding each
    // rank's optimizer-state bytes shrink with world size (MAC's rank-1
    // state and RK-FAC's sketches both shard per layer).
    let shapes: Vec<(usize, usize)> = vec![(48, 64), (32, 48), (16, 32), (4, 16)];
    for (method, hp) in zoo_cfgs() {
        let full = method.build(&shapes, &hp).state_bytes();
        for world in [2usize, 4] {
            let per_rank: Vec<usize> = (0..world)
                .map(|r| {
                    method
                        .build_dist(&shapes, &hp, DistCtx::new(DistStrategy::FactorSharded, r, world))
                        .state_bytes()
                })
                .collect();
            let total: usize = per_rank.iter().sum();
            assert_eq!(total, full, "{} world {world}: shards must partition", method.name());
            for (r, &b) in per_rank.iter().enumerate() {
                assert!(
                    b < full,
                    "{} world {world} rank {r}: {b} not < {full}",
                    method.name()
                );
            }
        }
    }
}
