//! Rank-invariance determinism suite for the distributed subsystem.
//!
//! Extends the serial/pooled bitwise-parity contract of
//! `rust/tests/parallel.rs` across world sizes: for power-of-two rank
//! counts dividing the batch size, the data-parallel driver must produce
//! *bitwise* identical losses and parameters to the serial path — under
//! both the replicated and factor-sharded strategies, on both rank
//! execution paths (pool workers and dedicated scoped threads).

use singd::data;
use singd::dist::{self, bucket, collectives, DistCtx, DistStrategy};
use singd::model::cnn::ImgShape;
use singd::model::{Mlp, Model};
use singd::optim::{Hyper, Method, Optimizer};
use singd::proptest::Pcg;
use singd::structured::Structure;
use singd::tensor::{pool, Mat};
use singd::train::{train_dist, train_image_model, DistCfg, RunResult, TrainCfg};

/// A 4-layer MLP job whose shapes satisfy the bitwise contract: batch 32
/// (power of two, divisible by 4 ranks), per-layer stats rows = 32.
fn fixture() -> (singd::data::Dataset, TrainCfg) {
    let mut rng = Pcg::new(2024);
    let ds = data::prototype_images(&mut rng, ImgShape { c: 1, h: 8, w: 8 }, 4, 128, 32, 2.0);
    let cfg = TrainCfg {
        method: Method::Singd { structure: Structure::Dense },
        hyper: Hyper { lr: 0.05, t_update: 1, riem_momentum: 0.6, ..Hyper::default() },
        epochs: 2,
        batch_size: 32,
        seed: 9,
        ..TrainCfg::default()
    };
    (ds, cfg)
}

fn fresh_model() -> Mlp {
    let mut rng = Pcg::new(77);
    Mlp::new(&mut rng, &[64, 48, 32, 16, 4])
}

/// Train from the fixed init; return the result and final parameters.
fn run(cfg: &TrainCfg, ds: &singd::data::Dataset, dc: Option<&DistCfg>) -> (RunResult, Vec<Mat>) {
    let mut model = fresh_model();
    let res = match dc {
        None => train_image_model(&mut model, ds, cfg),
        Some(dc) => train_dist(&mut model, ds, cfg, dc),
    };
    let params = model.params().clone();
    (res, params)
}

fn assert_bitwise_equal(a: &(RunResult, Vec<Mat>), b: &(RunResult, Vec<Mat>), ctx: &str) {
    assert_eq!(a.0.rows.len(), b.0.rows.len(), "{ctx}: row count");
    for (ra, rb) in a.0.rows.iter().zip(&b.0.rows) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{ctx}: train_loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.test_loss.to_bits(),
            rb.test_loss.to_bits(),
            "{ctx}: test_loss at step {}",
            ra.step
        );
        assert_eq!(ra.test_err.to_bits(), rb.test_err.to_bits(), "{ctx}: test_err");
    }
    assert_eq!(a.1.len(), b.1.len(), "{ctx}: layer count");
    for (l, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        assert!(pa.data() == pb.data(), "{ctx}: params of layer {l} diverged");
    }
}

#[test]
fn ranks1_is_bitwise_identical_to_serial() {
    let (ds, cfg) = fixture();
    let serial = run(&cfg, &ds, None);
    let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
    assert_bitwise_equal(&serial, &d1, "serial vs ranks=1");
}

#[test]
fn ranks4_replicated_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
    let d4 = run(&cfg, &ds, Some(&DistCfg { ranks: 4, strategy: DistStrategy::Replicated }));
    assert_bitwise_equal(&d1, &d4, "ranks=1 vs ranks=4 replicated");
}

#[test]
fn ranks4_factor_sharded_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
    let d4 = run(&cfg, &ds, Some(&DistCfg { ranks: 4, strategy: DistStrategy::FactorSharded }));
    assert_bitwise_equal(&d1, &d4, "ranks=1 vs ranks=4 factor-sharded");
}

#[test]
fn ranks2_matches_ranks1_bitwise() {
    let (ds, cfg) = fixture();
    let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let d2 = run(&cfg, &ds, Some(&DistCfg { ranks: 2, strategy }));
        assert_bitwise_equal(&d1, &d2, &format!("ranks=2 {}", strategy.name()));
    }
}

#[test]
fn singd_ranks_env_default_drives_dist_cfg_and_keeps_the_contract() {
    // ci.sh runs this suite under SINGD_RANKS ∈ {1, 4}: the env value
    // must flow into DistCfg::default() and the resulting world size
    // must uphold the bitwise contract against an explicit ranks=1 run.
    let dc = DistCfg::default();
    assert_eq!(dc.ranks, dist::default_ranks());
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    if dc.ranks.is_power_of_two() && cfg.batch_size % dc.ranks == 0 {
        let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
        let denv = run(&cfg, &ds, Some(&dc));
        assert_bitwise_equal(&d1, &denv, &format!("SINGD_RANKS={} default", dc.ranks));
    }
}

#[test]
fn kfac_rank_invariance() {
    let (ds, mut cfg) = fixture();
    cfg.method = Method::Kfac;
    cfg.hyper = Hyper { lr: 0.01, damping: 0.1, t_update: 1, update_clip: 0.05, ..Hyper::default() };
    cfg.epochs = 1;
    let d1 = run(&cfg, &ds, Some(&DistCfg { ranks: 1, strategy: DistStrategy::Replicated }));
    for strategy in [DistStrategy::Replicated, DistStrategy::FactorSharded] {
        let d4 = run(&cfg, &ds, Some(&DistCfg { ranks: 4, strategy }));
        assert_bitwise_equal(&d1, &d4, &format!("kfac ranks=4 {}", strategy.name()));
    }
}

#[test]
fn rank_execution_path_does_not_change_results() {
    // with_threads(4): ranks run on pool workers (when the pool is large
    // enough); with_threads(1): ranks run on dedicated scoped threads.
    // The collectives order reductions by rank index, so both paths must
    // be bitwise identical.
    let (ds, mut cfg) = fixture();
    cfg.epochs = 1;
    let dc = DistCfg { ranks: 4, strategy: DistStrategy::FactorSharded };
    let pooled = pool::with_threads(4, || run(&cfg, &ds, Some(&dc)));
    let threaded = pool::with_threads(1, || run(&cfg, &ds, Some(&dc)));
    assert_bitwise_equal(&pooled, &threaded, "pool vs scoped-thread ranks");
}

#[test]
fn factor_sharded_per_rank_state_shrinks_with_world_size() {
    let hp = Hyper::default();
    let method = Method::Singd { structure: Structure::Dense };
    // Heterogeneous layers: ranks partition the replicated state exactly.
    let mixed: Vec<(usize, usize)> = vec![(48, 64), (64, 96), (32, 48), (16, 32)];
    let full_mixed = method.build(&mixed, &hp).state_bytes();
    for world in [2usize, 4] {
        let per_rank: Vec<usize> = (0..world)
            .map(|r| {
                method
                    .build_dist(&mixed, &hp, DistCtx::new(DistStrategy::FactorSharded, r, world))
                    .state_bytes()
            })
            .collect();
        assert_eq!(per_rank.iter().sum::<usize>(), full_mixed, "world {world}");
    }
    // Equal layers: every rank holds exactly 1/world of the state.
    let equal: Vec<(usize, usize)> = vec![(32, 32); 8];
    let full_equal = method.build(&equal, &hp).state_bytes();
    for world in [2usize, 4, 8] {
        for r in 0..world {
            let b = method
                .build_dist(&equal, &hp, DistCtx::new(DistStrategy::FactorSharded, r, world))
                .state_bytes();
            assert_eq!(b * world, full_equal, "world {world} rank {r}");
        }
    }
}

#[test]
fn replicated_strategy_keeps_full_state_on_every_rank() {
    let shapes: Vec<(usize, usize)> = vec![(16, 16); 4];
    let hp = Hyper::default();
    let method = Method::Kfac;
    let full = method.build(&shapes, &hp).state_bytes();
    let r0 = method
        .build_dist(&shapes, &hp, DistCtx::new(DistStrategy::Replicated, 0, 4))
        .state_bytes();
    assert_eq!(r0, full);
}

#[test]
fn run_ranks_panic_propagates_and_pool_survives() {
    let out = std::panic::catch_unwind(|| {
        dist::run_ranks(4, |comm| {
            if comm.rank() == 2 {
                panic!("rank 2 exploded");
            }
            // Peers block on a collective; the poison must wake them.
            let _ = comm.exchange_f64(vec![comm.rank() as f64]);
        });
    });
    assert!(out.is_err(), "panic must propagate to the caller");
    // The pool and a fresh rendezvous must remain fully usable.
    let again = dist::run_ranks(4, |comm| {
        let parts = comm.exchange_f64(vec![comm.rank() as f64]);
        parts.iter().map(|p| p[0]).sum::<f64>()
    });
    assert_eq!(again, vec![6.0; 4]);
}

#[test]
fn bucketed_exchange_equals_per_layer_exchange_under_training_shapes() {
    // The exact shapes the factor-sharded driver exchanges: zero-padded
    // per-layer parameter updates of a 4-layer MLP.
    let mut rng = Pcg::new(31);
    let shapes = [(48usize, 65usize), (32, 49), (16, 33), (4, 17)];
    let world = 4;
    let values: Vec<Mat> = shapes.iter().map(|&(o, i)| rng.normal_mat(o, i, 0.1)).collect();
    let vals = &values;
    let outs = dist::run_ranks(world, |comm| {
        let mine: Vec<Mat> = vals
            .iter()
            .enumerate()
            .map(|(l, v)| {
                if dist::shard::round_robin_owner(l, world) == comm.rank() {
                    v.clone()
                } else {
                    Mat::zeros(v.rows(), v.cols())
                }
            })
            .collect();
        let mut bucketed = mine.clone();
        bucket::all_reduce_sum_bucketed(&comm, &mut bucketed, 1000);
        let plain = collectives::all_reduce_sum(&comm, &mine);
        (bucketed, plain)
    });
    for (bucketed, plain) in outs {
        for (l, ((b, p), want)) in bucketed.iter().zip(&plain).zip(vals).enumerate() {
            assert!(b.data() == p.data(), "layer {l}: bucketing changed bits");
            assert!(b.data() == want.data(), "layer {l}: zero-padded exchange not exact");
        }
    }
}
