//! Determinism & parallel-correctness suite for the worker-pool compute
//! substrate.
//!
//! Everything here pivots on one invariant: for every kernel in the crate,
//! the floating-point accumulation order of each output element is a
//! function of the problem shape alone — never of the thread count or the
//! sharding. So pooled runs must be *bitwise* identical to serial runs,
//! which is asserted with exact `data()` equality (not tolerances).
//!
//! The sharding factor is varied with `pool::with_threads` (the in-process
//! override of the `SINGD_THREADS` contract — the env var itself is read
//! once per process and can't be flipped inside a test binary).

use singd::optim::{Hyper, KronStats, Method};
use singd::proptest::Pcg;
use singd::structured::{proj, SMat, Structure};
use singd::tensor::{matmul, matmul_a_bt, matmul_at_b, pool, Mat};

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0f64;
            for p in 0..a.cols() {
                s += (a.at(i, p) as f64) * (b.at(p, j) as f64);
            }
            c.set(i, j, s as f32);
        }
    }
    c
}

fn assert_close(a: &Mat, b: &Mat, tol: f32, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (x, y) in a.data().iter().zip(b.data().iter()) {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{ctx}: {x} vs {y}"
        );
    }
}

/// Shapes that straddle every blocking boundary: MC=64, KC=256, NC=256,
/// MR=4, NR=16 — plus degenerate and skinny cases.
const SHAPES: &[(usize, usize, usize)] = &[
    (65, 257, 259),
    (64, 256, 256),
    (1, 1, 1),
    (5, 3, 7),
    (63, 511, 33),
    (3, 1000, 2),
    (130, 70, 18),
];

#[test]
fn matmul_matches_naive_across_thread_counts() {
    let mut rng = Pcg::new(101);
    for &(m, k, n) in SHAPES {
        let a = rng.normal_mat(m, k, 1.0);
        let b = rng.normal_mat(k, n, 1.0);
        let reference = naive_matmul(&a, &b);
        let serial = pool::with_threads(1, || matmul(&a, &b));
        let pooled = pool::with_threads(4, || matmul(&a, &b));
        assert_close(&serial, &reference, 1e-4, &format!("matmul {m}x{k}x{n} serial"));
        assert_eq!(
            serial.data(),
            pooled.data(),
            "matmul {m}x{k}x{n}: pooled result must be bitwise identical to serial"
        );
    }
}

#[test]
fn matmul_at_b_matches_naive_across_thread_counts() {
    let mut rng = Pcg::new(103);
    for &(m, k, n) in SHAPES {
        // A is (k x m): C = Aᵀ B with inner dim k.
        let a = rng.normal_mat(k, m, 1.0);
        let b = rng.normal_mat(k, n, 1.0);
        let reference = naive_matmul(&a.transpose(), &b);
        let serial = pool::with_threads(1, || matmul_at_b(&a, &b));
        let pooled = pool::with_threads(4, || matmul_at_b(&a, &b));
        assert_close(&serial, &reference, 1e-4, &format!("at_b {m}x{k}x{n} serial"));
        assert_eq!(
            serial.data(),
            pooled.data(),
            "at_b {m}x{k}x{n}: pooled result must be bitwise identical to serial"
        );
    }
}

#[test]
fn matmul_a_bt_matches_naive_across_thread_counts() {
    let mut rng = Pcg::new(107);
    for &(m, k, n) in SHAPES {
        let a = rng.normal_mat(m, k, 1.0);
        let b = rng.normal_mat(n, k, 1.0);
        let reference = naive_matmul(&a, &b.transpose());
        let serial = pool::with_threads(1, || matmul_a_bt(&a, &b));
        let pooled = pool::with_threads(4, || matmul_a_bt(&a, &b));
        assert_close(&serial, &reference, 1e-4, &format!("a_bt {m}x{k}x{n} serial"));
        assert_eq!(
            serial.data(),
            pooled.data(),
            "a_bt {m}x{k}x{n}: pooled result must be bitwise identical to serial"
        );
    }
}

#[test]
fn transpose_and_softmax_match_across_thread_counts() {
    let mut rng = Pcg::new(109);
    let x = rng.normal_mat(300, 257, 1.0);
    let t1 = pool::with_threads(1, || x.transpose());
    let t4 = pool::with_threads(4, || x.transpose());
    assert_eq!(t1.data(), t4.data(), "transpose");
    let s1 = pool::with_threads(1, || x.softmax_rows());
    let s4 = pool::with_threads(4, || x.softmax_rows());
    assert_eq!(s1.data(), s4.data(), "softmax_rows");
}

/// A well-conditioned random element of each structure class, at sizes
/// large enough to clear the structured-op parallel thresholds.
fn structured_cases(rng: &mut Pcg) -> Vec<(SMat, usize)> {
    let mut cases = Vec::new();
    for (s, d) in [
        (Structure::Dense, 96),
        (Structure::Diagonal, 256),
        (Structure::BlockDiag { k: 32 }, 256),
        (Structure::Tril, 128),
        (Structure::RankKTril { k: 4 }, 128),
        (Structure::Hierarchical { k1: 8, k2: 8 }, 128),
        (Structure::TriuToeplitz, 128),
    ] {
        let sym = rng.normal_mat(d, d, 0.3).symmetrize();
        let mut k = proj::proj(s, &sym);
        k.axpy(1.0, &SMat::identity(s, d));
        cases.push((k, d));
    }
    cases
}

#[test]
fn structured_ops_bitwise_identical_serial_vs_pooled() {
    let mut rng = Pcg::new(113);
    for (k, d) in structured_cases(&mut rng) {
        let name = k.structure().name();
        let x = rng.normal_mat(512, d, 1.0);
        let y = rng.normal_mat(d, 96, 1.0);
        for transpose in [false, true] {
            let r1 = pool::with_threads(1, || k.right_mul(&x, transpose));
            let r4 = pool::with_threads(4, || k.right_mul(&x, transpose));
            assert_eq!(r1.data(), r4.data(), "{name} right_mul t={transpose}");
            let l1 = pool::with_threads(1, || k.left_mul(&y, transpose));
            let l4 = pool::with_threads(4, || k.left_mul(&y, transpose));
            assert_eq!(l1.data(), l4.data(), "{name} left_mul t={transpose}");
        }
        let g1 = pool::with_threads(1, || k.gram_project(&x, 0.35));
        let g4 = pool::with_threads(4, || k.gram_project(&x, 0.35));
        assert_eq!(
            g1.to_dense().data(),
            g4.to_dense().data(),
            "{name} gram_project"
        );
        let other = SMat::identity(k.structure(), d);
        let m1 = pool::with_threads(1, || k.matmul(&other));
        let m4 = pool::with_threads(4, || k.matmul(&other));
        assert_eq!(m1.to_dense().data(), m4.to_dense().data(), "{name} matmul");
        let kk1 = pool::with_threads(1, || k.kkt_right(&x));
        let kk4 = pool::with_threads(4, || k.kkt_right(&x));
        assert_eq!(kk1.data(), kk4.data(), "{name} kkt_right");
    }
}

/// Run `steps` SINGD steps on synthetic multi-layer data and return the
/// final parameters plus densified preconditioner factors.
fn singd_trajectory(method: &Method, steps: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Pcg::new(seed);
    let shapes = [(48usize, 64usize), (64, 96), (32, 48)];
    let m = 192;
    let hp = Hyper { t_update: 1, riem_momentum: 0.6, ..Hyper::default() };
    let mut opt = method.build(&shapes, &hp);
    let mut params: Vec<Mat> =
        shapes.iter().map(|&(o, i)| rng.normal_mat(o, i, 0.2)).collect();
    // Fixed per-step data, regenerated identically per trajectory.
    for t in 0..steps {
        let mut data_rng = Pcg::with_stream(seed, t as u64 + 1);
        let grads: Vec<Mat> =
            shapes.iter().map(|&(o, i)| data_rng.normal_mat(o, i, 0.1)).collect();
        let stats: Vec<KronStats> = shapes
            .iter()
            .map(|&(o, i)| KronStats {
                a: data_rng.normal_mat(m, i, 1.0),
                g: data_rng.normal_mat(m, o, 1.0),
            })
            .collect();
        opt.step(t, &mut params, &grads, &stats);
    }
    params
}

#[test]
fn singd_step_trajectory_identical_serial_vs_pooled() {
    for method in [
        Method::Singd { structure: Structure::Dense },
        Method::Singd { structure: Structure::Hierarchical { k1: 8, k2: 8 } },
        Method::Singd { structure: Structure::BlockDiag { k: 16 } },
    ] {
        let serial = pool::with_threads(1, || singd_trajectory(&method, 4, 131));
        let pooled = pool::with_threads(4, || singd_trajectory(&method, 4, 131));
        assert_eq!(serial.len(), pooled.len());
        for (l, (ws, wp)) in serial.iter().zip(pooled.iter()).enumerate() {
            assert!(
                ws.data() == wp.data(),
                "{} layer {l}: pooled trajectory diverged from serial",
                method.name()
            );
        }
    }
}

#[test]
fn kfac_step_trajectory_identical_serial_vs_pooled() {
    let method = Method::Kfac;
    let serial = pool::with_threads(1, || singd_trajectory(&method, 3, 137));
    let pooled = pool::with_threads(4, || singd_trajectory(&method, 3, 137));
    for (l, (ws, wp)) in serial.iter().zip(pooled.iter()).enumerate() {
        assert!(
            ws.data() == wp.data(),
            "kfac layer {l}: pooled trajectory diverged from serial"
        );
    }
}
