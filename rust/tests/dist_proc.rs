//! Multi-process transport acceptance suite (ISSUE 3).
//!
//! Drives the actual `singd` binary (`CARGO_BIN_EXE_singd`) end to end:
//! `train --transport socket --ranks 4` makes the launched process rank 0
//! of a real 4-OS-process world (ranks 1–3 are re-exec'd workers joined
//! over a Unix-socket rendezvous). The run's `param_digest` — an FNV-1a
//! digest over every logged loss bit and every final parameter bit —
//! must be identical to `--transport local` and to serial `--ranks 1`,
//! for SINGD and KFAC, under both the replicated and factor-sharded
//! strategies. ci.sh runs this suite under a hard timeout so a hung
//! rendezvous fails fast instead of stalling CI.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_singd")
}

/// A tiny deterministic job: 4-batch MLP epochs over the synthetic
/// CIFAR stand-in (seconds per run, exercises the full dist stack).
fn write_job_epochs(name: &str, method: &str, epochs: usize) -> PathBuf {
    let toml = format!(
        "label = \"dist-proc\"\n\
         [model]\narch = \"mlp\"\nwidth = 32\n\
         [data]\nclasses = 4\nn_train = 128\nn_test = 32\n\
         [optim]\nmethod = \"{method}\"\nlr = 0.01\ndamping = 0.1\nt_update = 1\n\
         [train]\nepochs = {epochs}\nbatch_size = 32\nseed = 11\n"
    );
    let path = std::env::temp_dir()
        .join(format!("singd-dist-proc-{}-{name}.toml", std::process::id()));
    std::fs::write(&path, toml).expect("write job config");
    path
}

fn write_job(name: &str, method: &str) -> PathBuf {
    write_job_epochs(name, method, 1)
}

/// The SINGD_* knobs cleared from child environments so the CI matrix
/// (and a previous chaos run) cannot leak a world size, transport, fault
/// injection or observability setting into the child. SINGD_LOG matters
/// doubly here: a leaked `error` level would silence the `param_digest`
/// line these tests parse.
const CLEARED_ENV: [&str; 12] = [
    "SINGD_RANKS",
    "SINGD_TRANSPORT",
    "SINGD_ALGO",
    "SINGD_OVERLAP",
    "SINGD_STREAM",
    "SINGD_RANK",
    "SINGD_WORLD",
    "SINGD_RENDEZVOUS",
    "SINGD_RUN_ID",
    "SINGD_CHAOS_ABORT",
    "SINGD_TRACE",
    "SINGD_LOG",
];

/// Run `singd train` with the given extra flags; return its param digest.
fn digest_of(config: &std::path::Path, extra: &[&str]) -> String {
    digest_of_env(config, extra, &[])
}

/// [`digest_of`] with explicit extra environment variables (set after
/// the [`CLEARED_ENV`] scrub — the chaos test injects its kill knob
/// here).
fn digest_of_env(config: &std::path::Path, extra: &[&str], envs: &[(&str, &str)]) -> String {
    let mut cmd = Command::new(bin());
    cmd.arg("train").arg("--config").arg(config).args(extra);
    for k in CLEARED_ENV {
        cmd.env_remove(k);
    }
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn singd");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "singd train {extra:?} failed ({}):\nstdout: {stdout}\nstderr: {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let tok = stdout
        .split_whitespace()
        .skip_while(|t| *t != "param_digest")
        .nth(1)
        .unwrap_or_else(|| panic!("no param_digest in output:\n{stdout}"))
        .to_string();
    assert_eq!(tok.len(), 16, "malformed digest '{tok}'");
    tok
}

#[test]
fn socket_ranks4_bitwise_matches_local_and_serial_for_singd_and_kfac() {
    for method in ["singd:diag", "kfac"] {
        let cfg = write_job(&method.replace(':', "-"), method);
        let serial = digest_of(&cfg, &["--ranks", "1"]);
        for strategy in ["replicated", "factor-sharded"] {
            // The default algo is ring; these two legs are the headline
            // "--algo ring on both transports" acceptance.
            let ring: &[&str] = &["--ranks", "4", "--strategy", strategy, "--algo", "ring"];
            let local = digest_of(&cfg, &[ring, &["--transport", "local"][..]].concat());
            let socket = digest_of(&cfg, &[ring, &["--transport", "socket"][..]].concat());
            assert_eq!(
                serial, local,
                "{method}/{strategy}: local ring ranks=4 diverged from serial"
            );
            assert_eq!(
                serial, socket,
                "{method}/{strategy}: socket ring ranks=4 (separate processes) diverged from serial"
            );
        }
        std::fs::remove_file(&cfg).ok();
    }
}

#[test]
fn socket_ranks4_digest_matches_serial_for_rkfac_and_mac() {
    // The optimizer-zoo acceptance (ISSUE 10) over real OS processes:
    // one pruned digest leg per new optimizer. RK-FAC's sketch is
    // re-derived per (layer, refresh-step) from a rank-independent
    // seed and MAC's mean-activation vector reduces like any factor,
    // so a real 4-process socket world must digest identically to
    // serial. One factor-sharded ring cell each keeps the process
    // count bounded; the full strategy × algo × stream grid runs
    // in-process in rust/tests/dist.rs.
    for method in ["rkfac", "mac"] {
        let cfg = write_job(method, method);
        let serial = digest_of(&cfg, &["--ranks", "1"]);
        let socket = digest_of(
            &cfg,
            &[
                "--ranks",
                "4",
                "--strategy",
                "factor-sharded",
                "--transport",
                "socket",
                "--algo",
                "ring",
            ],
        );
        assert_eq!(
            serial, socket,
            "{method}: socket ring ranks=4 (separate processes) diverged from serial"
        );
        std::fs::remove_file(&cfg).ok();
    }
}

#[test]
fn star_and_ring_digests_match_across_transports() {
    // The algo axis end to end over real OS processes: star and ring
    // must produce identical param digests on both transports (one
    // method/strategy cell keeps the process count bounded; the full
    // shape grid lives in the in-process conformance suite).
    let cfg = write_job("algo-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for algo in ["star", "ring"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    algo,
                ],
            );
            assert_eq!(serial, digest, "{transport}/{algo}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn overlap_axis_digests_match_across_transports_and_processes() {
    // The overlap-invariance contract (ARCHITECTURE.md contract 4) over
    // real OS processes: --overlap 0 and --overlap 1 must produce
    // identical param digests on both transports — overlap reorders
    // *time*, never *reduction order*. One method under factor sharding
    // keeps the process count bounded; the full strategy × algo ×
    // overlap grid runs in-process in rust/tests/dist.rs.
    let cfg = write_job("overlap-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for overlap in ["0", "1"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    "ring",
                    "--overlap",
                    overlap,
                ],
            );
            assert_eq!(serial, digest, "{transport}/overlap={overlap}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn stream_axis_digests_match_across_transports_and_processes() {
    // The stream-invariance contract (ARCHITECTURE.md contract 8) over
    // real OS processes: --stream 0 and --stream 1 must produce
    // identical param digests on both transports — streaming moves each
    // layer's stats gather *into* the backward pass (issued from the
    // per-layer hook), which reorders *issue time*, never data or
    // reduction order. The launcher pins SINGD_STREAM into re-exec'd
    // workers, so the socket leg also proves the env propagation: a
    // mixed world would deadlock, not merely diverge. One method under
    // factor sharding keeps the process count bounded; the full
    // strategy × algo × R × method grid runs in-process in
    // rust/tests/dist.rs.
    let cfg = write_job("stream-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for stream in ["0", "1"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    "ring",
                    "--overlap",
                    "1",
                    "--stream",
                    stream,
                ],
            );
            assert_eq!(serial, digest, "{transport}/stream={stream}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn accum_steps_digest_matches_unsplit_across_processes() {
    // Gradient accumulation end to end through the CLI: splitting every
    // step of a 32-row batch into 2 and 4 power-of-two micro-batches
    // must reproduce the unsplit digest bit for bit — serial and over a
    // real 4-process socket world (8-row shards → 4- and 2-row micros).
    let cfg = write_job("accum", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for k in ["2", "4"] {
        let split = digest_of(&cfg, &["--ranks", "1", "--accum-steps", k]);
        assert_eq!(serial, split, "serial accum-steps={k}: diverged from unsplit");
        let socket = digest_of(
            &cfg,
            &[
                "--ranks",
                "4",
                "--strategy",
                "factor-sharded",
                "--transport",
                "socket",
                "--accum-steps",
                k,
            ],
        );
        assert_eq!(serial, socket, "socket ranks=4 accum-steps={k}: diverged from unsplit");
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn socket_ranks2_smoke_with_csv_output() {
    // The multi-process smoke documented in README §Distributed: socket
    // transport also writes the rank-0 CSV, and workers stay silent.
    let cfg = write_job("smoke", "sgd");
    let out_csv = std::env::temp_dir()
        .join(format!("singd-dist-proc-smoke-{}.csv", std::process::id()));
    let mut cmd = Command::new(bin());
    cmd.arg("train")
        .arg("--config")
        .arg(&cfg)
        .args(["--ranks", "2", "--transport", "socket", "--out"])
        .arg(&out_csv);
    for k in CLEARED_ENV {
        cmd.env_remove(k);
    }
    let out = cmd.output().expect("spawn singd");
    assert!(
        out.status.success(),
        "socket smoke failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&out_csv).expect("rank 0 must write the CSV");
    assert!(csv.starts_with("label,step"), "csv header");
    assert!(csv.lines().count() >= 2, "csv rows");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&out_csv).ok();
}

// =====================================================================
// Elastic fault tolerance over real OS processes (ISSUE 6).

#[test]
fn resume_socket_matches_uninterrupted_digest() {
    // Checkpoint/resume across real processes: a 1-epoch socket run that
    // checkpoints every 2 steps, resumed into the 2-epoch schedule, must
    // digest identically to the uninterrupted 2-epoch socket run. Every
    // rank (parent and re-exec'd workers) reads the checkpoint off the
    // shared filesystem and re-deals the canonical state.
    let cfg1 = write_job_epochs("resume-1", "singd:diag", 1);
    let cfg2 = write_job_epochs("resume-2", "singd:diag", 2);
    let ckpt = std::env::temp_dir()
        .join(format!("singd-dist-proc-resume-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let common: &[&str] =
        &["--ranks", "4", "--strategy", "factor-sharded", "--transport", "socket"];
    let full = digest_of(&cfg2, common);
    let _ = digest_of(&cfg1, &[common, &["--ckpt", &ckpt_s, "--ckpt-every", "2"][..]].concat());
    assert!(ckpt.exists(), "socket run must write the checkpoint");
    let resumed = digest_of(&cfg2, &[common, &["--resume", &ckpt_s][..]].concat());
    assert_eq!(full, resumed, "socket resume diverged from the uninterrupted run");
    std::fs::remove_file(&cfg1).ok();
    std::fs::remove_file(&cfg2).ok();
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(format!("{ckpt_s}.prev")).ok();
}

#[test]
fn elastic_chaos_kill_worker_midstep_reshards_and_matches_uninterrupted() {
    // The chaos acceptance (ISSUE 6): rank 2 of an elastic 4-process
    // world hard-aborts (std::process::abort — severed sockets, no
    // goodbye) just before step 3 of an 8-step run checkpointing every
    // 2 steps. Survivors must observe the EOF, re-rendezvous into
    // generation 1 as world 3, reload the step-2 checkpoint, re-deal the
    // canonical optimizer state to 3 ranks and finish — and the digest
    // must equal an uninterrupted ranks=3 run resumed from the exact
    // recovery checkpoint (preserved as `<ckpt>.resharded-g1`).
    let cfg = write_job_epochs("chaos", "singd:diag", 2);
    let ckpt = std::env::temp_dir()
        .join(format!("singd-dist-proc-chaos-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let interrupted = digest_of_env(
        &cfg,
        &[
            "--ranks",
            "4",
            "--strategy",
            "factor-sharded",
            "--transport",
            "socket",
            "--elastic",
            "1",
            "--ckpt",
            &ckpt_s,
            "--ckpt-every",
            "2",
        ],
        &[("SINGD_CHAOS_ABORT", "2:3"), ("SINGD_SOCK_TIMEOUT_SECS", "20")],
    );
    let resharded = format!("{ckpt_s}.resharded-g1");
    assert!(
        std::path::Path::new(&resharded).exists(),
        "regroup must snapshot the recovery checkpoint as {resharded}"
    );
    let uninterrupted = digest_of(
        &cfg,
        &[
            "--ranks",
            "3",
            "--strategy",
            "factor-sharded",
            "--transport",
            "socket",
            "--resume",
            &resharded,
        ],
    );
    assert_eq!(
        interrupted, uninterrupted,
        "interrupted+resharded R=4→R'=3 run diverged from the uninterrupted \
         R'=3 run resumed from the same checkpoint"
    );
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(&resharded).ok();
    std::fs::remove_file(format!("{ckpt_s}.prev")).ok();
}

// =====================================================================
// Observability over real OS processes (ISSUE 7).

/// Parse a journal file into (`step` span intervals, phase intervals).
/// Every line must be a well-formed single-object journal record; the
/// child process ran exactly one job, so — unlike the in-process suite —
/// the artifact is pristine and the checks can be exhaustive.
fn parse_journal(path: &std::path::Path, rank: u64) -> (Vec<(u64, u64)>, Vec<(String, u64, u64)>) {
    let jsonl = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    assert!(!jsonl.trim().is_empty(), "{} is empty", path.display());
    let mut steps = Vec::new();
    let mut phases = Vec::new();
    for line in jsonl.lines() {
        assert!(line.starts_with('{') && line.ends_with('}'), "bad journal line {line:?}");
        for key in ["\"name\":", "\"cat\":", "\"ph\":", "\"ts_us\":", "\"dur_us\":", "\"args\":"] {
            assert!(line.contains(key), "journal line missing {key}: {line}");
        }
        let field = |k: &str| -> u64 {
            let tail = &line[line.find(k).unwrap_or_else(|| panic!("no {k} in {line}")) + k.len()..];
            let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits.parse().unwrap_or_else(|e| panic!("bad {k} in {line}: {e}"))
        };
        assert_eq!(field("\"rank\":"), rank, "event on a foreign rank: {line}");
        let name = {
            let tail = &line[line.find("\"name\":\"").unwrap() + 8..];
            tail[..tail.find('"').unwrap()].to_string()
        };
        let (ts, dur) = (field("\"ts_us\":"), field("\"dur_us\":"));
        if name == "step" {
            steps.push((ts, ts + dur));
        } else if ["forward_backward", "grad_reconstruct", "precond_update"]
            .contains(&name.as_str())
        {
            phases.push((name, ts, ts + dur));
        }
    }
    (steps, phases)
}

fn assert_phases_nest(steps: &[(u64, u64)], phases: &[(String, u64, u64)], ctx: &str) {
    assert!(!steps.is_empty(), "{ctx}: no step spans");
    assert!(
        phases.iter().any(|(n, _, _)| n == "forward_backward"),
        "{ctx}: no forward_backward phase"
    );
    for (name, a, b) in phases {
        assert!(
            steps.iter().any(|(sa, sb)| sa <= a && b <= sb),
            "{ctx}: phase {name} [{a},{b}] not nested in any step span"
        );
    }
}

#[test]
fn traced_runs_digest_identically_and_export_per_rank_artifacts() {
    // The sixth contract end to end through the CLI: SINGD_TRACE /
    // --trace-dir must not perturb the param digest on either transport,
    // and every rank — including re-exec'd socket worker processes, which
    // inherit the dir via the pinned SINGD_TRACE env — exports its
    // r<N>.jsonl + r<N>.trace.json pair.
    let cfg = write_job("traced", "singd:diag");
    let common: &[&str] = &["--ranks", "4", "--strategy", "factor-sharded", "--algo", "ring"];
    for transport in ["local", "socket"] {
        let plain =
            digest_of(&cfg, &[common, &["--transport", transport][..]].concat());
        let dir = std::env::temp_dir()
            .join(format!("singd-proc-trace-{}-{transport}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        // local leg exercises the --trace-dir flag, socket leg the
        // SINGD_TRACE env default — both plumbing paths end at the same
        // exporter.
        let traced = if transport == "local" {
            digest_of(
                &cfg,
                &[common, &["--transport", transport, "--trace-dir", &dir_s][..]].concat(),
            )
        } else {
            digest_of_env(
                &cfg,
                &[common, &["--transport", transport][..]].concat(),
                &[("SINGD_TRACE", &dir_s)],
            )
        };
        assert_eq!(plain, traced, "{transport}: tracing changed the param digest");
        for r in 0..4u64 {
            let journal = dir.join(format!("r{r}.jsonl"));
            assert!(journal.exists(), "{transport}: missing {}", journal.display());
            let chrome = std::fs::read_to_string(dir.join(format!("r{r}.trace.json")))
                .unwrap_or_else(|e| panic!("{transport}: r{r}.trace.json: {e}"));
            assert!(chrome.starts_with("{\"traceEvents\":["), "{transport}: chrome header");
            assert!(chrome.trim_end().ends_with("]}"), "{transport}: chrome footer");
            // Each socket process drives its own train loop, so every
            // rank file is self-contained: steps and phases share the
            // process clock and must nest exhaustively. Local transport
            // is one process with one session clock — its `step` spans
            // live on the driver thread (rank 0) and worker ranks carry
            // phases only, so nesting is checked globally below.
            let (steps, phases) = parse_journal(&journal, r);
            if transport == "socket" {
                assert_phases_nest(&steps, &phases, &format!("socket r{r}"));
            }
        }
        if transport == "local" {
            let (steps, _) = parse_journal(&dir.join("r0.jsonl"), 0);
            for r in 0..4u64 {
                let (_, phases) = parse_journal(&dir.join(format!("r{r}.jsonl")), r);
                assert_phases_nest(&steps, &phases, &format!("local r{r}"));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn traced_stream_run_issues_gathers_inside_backward_and_only_then() {
    // The trace-backed overlap regression for streaming (ISSUE 9): in a
    // pristine single-job process, --stream 1 must record a
    // `layer_gather_issue` span that *begins before the enclosing
    // `forward_backward` span ends* — the gather demonstrably launches
    // while the backward is still running — and --stream 0 must record
    // none at all (its gathers are issued after the backward returns,
    // under other span names). The in-process suite cannot pin the
    // absence half (the trace session is process-global and concurrent
    // tests stream by default); this child process runs exactly one job,
    // so the check is exact. tools/check_trace.py enforces the same
    // nesting rule on any journal it is handed.
    let cfg = write_job("stream-traced", "singd:diag");
    for stream in ["0", "1"] {
        let dir = std::env::temp_dir().join(format!(
            "singd-proc-trace-stream-{}-{stream}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        let _ = digest_of(
            &cfg,
            &[
                "--ranks",
                "2",
                "--transport",
                "local",
                "--algo",
                "ring",
                "--overlap",
                "1",
                "--stream",
                stream,
                "--trace-dir",
                &dir_s,
            ],
        );
        let mut issues = 0usize;
        for r in 0..2u64 {
            let journal = dir.join(format!("r{r}.jsonl"));
            let jsonl = std::fs::read_to_string(&journal)
                .unwrap_or_else(|e| panic!("{}: {e}", journal.display()));
            let mut fb: Vec<(u64, u64)> = Vec::new();
            let mut gi: Vec<(u64, u64)> = Vec::new();
            for line in jsonl.lines() {
                let field = |k: &str| -> u64 {
                    let tail = &line
                        [line.find(k).unwrap_or_else(|| panic!("no {k} in {line}")) + k.len()..];
                    let digits: String =
                        tail.chars().take_while(|c| c.is_ascii_digit()).collect();
                    digits.parse().unwrap_or_else(|e| panic!("bad {k} in {line}: {e}"))
                };
                let interval = || (field("\"ts_us\":"), field("\"ts_us\":") + field("\"dur_us\":"));
                if line.contains("\"name\":\"forward_backward\"") {
                    fb.push(interval());
                } else if line.contains("\"name\":\"layer_gather_issue\"") {
                    gi.push(interval());
                }
            }
            if stream == "0" {
                assert!(
                    gi.is_empty(),
                    "r{r}: layer_gather_issue spans recorded with --stream 0"
                );
            } else {
                assert!(!fb.is_empty(), "r{r}: no forward_backward spans");
                for (a, b) in &gi {
                    assert!(
                        fb.iter().any(|(fa, fe)| fa <= a && b <= fe),
                        "r{r}: layer_gather_issue [{a},{b}] does not nest inside any \
                         forward_backward span {fb:?}"
                    );
                }
                issues += gi.len();
            }
        }
        if stream == "1" {
            // 2 ranks × 4 layers × ≥4 steps — every layer's gather must
            // have been issued from inside some backward.
            assert!(issues >= 8, "too few layer_gather_issue spans: {issues}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn traced_elastic_chaos_digest_matches_untraced_and_records_regroup() {
    // Tracing must stay non-interfering through the hardest path: a
    // worker hard-abort mid-step, EOF detection, re-rendezvous and
    // checkpoint reshard. The traced interrupted run must digest
    // identically to the untraced interrupted run, and the coordinator's
    // journal must carry the `regroup` elastic instant.
    let cfg = write_job_epochs("chaos-traced", "singd:diag", 2);
    let dir = std::env::temp_dir()
        .join(format!("singd-proc-trace-chaos-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().unwrap().to_string();
    let mut digests = Vec::new();
    for traced in [false, true] {
        let ckpt = std::env::temp_dir().join(format!(
            "singd-proc-trace-chaos-{}-{traced}.ckpt",
            std::process::id()
        ));
        let ckpt_s = ckpt.to_str().unwrap().to_string();
        let mut envs: Vec<(&str, &str)> =
            vec![("SINGD_CHAOS_ABORT", "2:3"), ("SINGD_SOCK_TIMEOUT_SECS", "20")];
        if traced {
            envs.push(("SINGD_TRACE", &dir_s));
        }
        digests.push(digest_of_env(
            &cfg,
            &[
                "--ranks",
                "4",
                "--strategy",
                "factor-sharded",
                "--transport",
                "socket",
                "--elastic",
                "1",
                "--ckpt",
                &ckpt_s,
                "--ckpt-every",
                "2",
            ],
            &envs,
        ));
        std::fs::remove_file(&ckpt).ok();
        std::fs::remove_file(format!("{ckpt_s}.prev")).ok();
        std::fs::remove_file(format!("{ckpt_s}.resharded-g1")).ok();
    }
    assert_eq!(digests[0], digests[1], "tracing changed the elastic chaos digest");
    let r0 = std::fs::read_to_string(dir.join("r0.jsonl"))
        .expect("coordinator must export its journal");
    assert!(r0.contains("\"name\":\"regroup\""), "no regroup instant in coordinator journal");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::remove_file(&cfg).ok();
}
