//! Multi-process transport acceptance suite (ISSUE 3).
//!
//! Drives the actual `singd` binary (`CARGO_BIN_EXE_singd`) end to end:
//! `train --transport socket --ranks 4` makes the launched process rank 0
//! of a real 4-OS-process world (ranks 1–3 are re-exec'd workers joined
//! over a Unix-socket rendezvous). The run's `param_digest` — an FNV-1a
//! digest over every logged loss bit and every final parameter bit —
//! must be identical to `--transport local` and to serial `--ranks 1`,
//! for SINGD and KFAC, under both the replicated and factor-sharded
//! strategies. ci.sh runs this suite under a hard timeout so a hung
//! rendezvous fails fast instead of stalling CI.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_singd")
}

/// A tiny deterministic job: 4-batch MLP epochs over the synthetic
/// CIFAR stand-in (seconds per run, exercises the full dist stack).
fn write_job_epochs(name: &str, method: &str, epochs: usize) -> PathBuf {
    let toml = format!(
        "label = \"dist-proc\"\n\
         [model]\narch = \"mlp\"\nwidth = 32\n\
         [data]\nclasses = 4\nn_train = 128\nn_test = 32\n\
         [optim]\nmethod = \"{method}\"\nlr = 0.01\ndamping = 0.1\nt_update = 1\n\
         [train]\nepochs = {epochs}\nbatch_size = 32\nseed = 11\n"
    );
    let path = std::env::temp_dir()
        .join(format!("singd-dist-proc-{}-{name}.toml", std::process::id()));
    std::fs::write(&path, toml).expect("write job config");
    path
}

fn write_job(name: &str, method: &str) -> PathBuf {
    write_job_epochs(name, method, 1)
}

/// The SINGD_* knobs cleared from child environments so the CI matrix
/// (and a previous chaos run) cannot leak a world size, transport or
/// fault injection into the child.
const CLEARED_ENV: [&str; 9] = [
    "SINGD_RANKS",
    "SINGD_TRANSPORT",
    "SINGD_ALGO",
    "SINGD_OVERLAP",
    "SINGD_RANK",
    "SINGD_WORLD",
    "SINGD_RENDEZVOUS",
    "SINGD_RUN_ID",
    "SINGD_CHAOS_ABORT",
];

/// Run `singd train` with the given extra flags; return its param digest.
fn digest_of(config: &std::path::Path, extra: &[&str]) -> String {
    digest_of_env(config, extra, &[])
}

/// [`digest_of`] with explicit extra environment variables (set after
/// the [`CLEARED_ENV`] scrub — the chaos test injects its kill knob
/// here).
fn digest_of_env(config: &std::path::Path, extra: &[&str], envs: &[(&str, &str)]) -> String {
    let mut cmd = Command::new(bin());
    cmd.arg("train").arg("--config").arg(config).args(extra);
    for k in CLEARED_ENV {
        cmd.env_remove(k);
    }
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn singd");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "singd train {extra:?} failed ({}):\nstdout: {stdout}\nstderr: {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let tok = stdout
        .split_whitespace()
        .skip_while(|t| *t != "param_digest")
        .nth(1)
        .unwrap_or_else(|| panic!("no param_digest in output:\n{stdout}"))
        .to_string();
    assert_eq!(tok.len(), 16, "malformed digest '{tok}'");
    tok
}

#[test]
fn socket_ranks4_bitwise_matches_local_and_serial_for_singd_and_kfac() {
    for method in ["singd:diag", "kfac"] {
        let cfg = write_job(&method.replace(':', "-"), method);
        let serial = digest_of(&cfg, &["--ranks", "1"]);
        for strategy in ["replicated", "factor-sharded"] {
            // The default algo is ring; these two legs are the headline
            // "--algo ring on both transports" acceptance.
            let ring: &[&str] = &["--ranks", "4", "--strategy", strategy, "--algo", "ring"];
            let local = digest_of(&cfg, &[ring, &["--transport", "local"][..]].concat());
            let socket = digest_of(&cfg, &[ring, &["--transport", "socket"][..]].concat());
            assert_eq!(
                serial, local,
                "{method}/{strategy}: local ring ranks=4 diverged from serial"
            );
            assert_eq!(
                serial, socket,
                "{method}/{strategy}: socket ring ranks=4 (separate processes) diverged from serial"
            );
        }
        std::fs::remove_file(&cfg).ok();
    }
}

#[test]
fn star_and_ring_digests_match_across_transports() {
    // The algo axis end to end over real OS processes: star and ring
    // must produce identical param digests on both transports (one
    // method/strategy cell keeps the process count bounded; the full
    // shape grid lives in the in-process conformance suite).
    let cfg = write_job("algo-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for algo in ["star", "ring"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    algo,
                ],
            );
            assert_eq!(serial, digest, "{transport}/{algo}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn overlap_axis_digests_match_across_transports_and_processes() {
    // The overlap-invariance contract (ARCHITECTURE.md contract 4) over
    // real OS processes: --overlap 0 and --overlap 1 must produce
    // identical param digests on both transports — overlap reorders
    // *time*, never *reduction order*. One method under factor sharding
    // keeps the process count bounded; the full strategy × algo ×
    // overlap grid runs in-process in rust/tests/dist.rs.
    let cfg = write_job("overlap-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for overlap in ["0", "1"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    "ring",
                    "--overlap",
                    overlap,
                ],
            );
            assert_eq!(serial, digest, "{transport}/overlap={overlap}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn socket_ranks2_smoke_with_csv_output() {
    // The multi-process smoke documented in README §Distributed: socket
    // transport also writes the rank-0 CSV, and workers stay silent.
    let cfg = write_job("smoke", "sgd");
    let out_csv = std::env::temp_dir()
        .join(format!("singd-dist-proc-smoke-{}.csv", std::process::id()));
    let mut cmd = Command::new(bin());
    cmd.arg("train")
        .arg("--config")
        .arg(&cfg)
        .args(["--ranks", "2", "--transport", "socket", "--out"])
        .arg(&out_csv);
    for k in CLEARED_ENV {
        cmd.env_remove(k);
    }
    let out = cmd.output().expect("spawn singd");
    assert!(
        out.status.success(),
        "socket smoke failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&out_csv).expect("rank 0 must write the CSV");
    assert!(csv.starts_with("label,step"), "csv header");
    assert!(csv.lines().count() >= 2, "csv rows");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&out_csv).ok();
}

// =====================================================================
// Elastic fault tolerance over real OS processes (ISSUE 6).

#[test]
fn resume_socket_matches_uninterrupted_digest() {
    // Checkpoint/resume across real processes: a 1-epoch socket run that
    // checkpoints every 2 steps, resumed into the 2-epoch schedule, must
    // digest identically to the uninterrupted 2-epoch socket run. Every
    // rank (parent and re-exec'd workers) reads the checkpoint off the
    // shared filesystem and re-deals the canonical state.
    let cfg1 = write_job_epochs("resume-1", "singd:diag", 1);
    let cfg2 = write_job_epochs("resume-2", "singd:diag", 2);
    let ckpt = std::env::temp_dir()
        .join(format!("singd-dist-proc-resume-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let common: &[&str] =
        &["--ranks", "4", "--strategy", "factor-sharded", "--transport", "socket"];
    let full = digest_of(&cfg2, common);
    let _ = digest_of(&cfg1, &[common, &["--ckpt", &ckpt_s, "--ckpt-every", "2"][..]].concat());
    assert!(ckpt.exists(), "socket run must write the checkpoint");
    let resumed = digest_of(&cfg2, &[common, &["--resume", &ckpt_s][..]].concat());
    assert_eq!(full, resumed, "socket resume diverged from the uninterrupted run");
    std::fs::remove_file(&cfg1).ok();
    std::fs::remove_file(&cfg2).ok();
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(format!("{ckpt_s}.prev")).ok();
}

#[test]
fn elastic_chaos_kill_worker_midstep_reshards_and_matches_uninterrupted() {
    // The chaos acceptance (ISSUE 6): rank 2 of an elastic 4-process
    // world hard-aborts (std::process::abort — severed sockets, no
    // goodbye) just before step 3 of an 8-step run checkpointing every
    // 2 steps. Survivors must observe the EOF, re-rendezvous into
    // generation 1 as world 3, reload the step-2 checkpoint, re-deal the
    // canonical optimizer state to 3 ranks and finish — and the digest
    // must equal an uninterrupted ranks=3 run resumed from the exact
    // recovery checkpoint (preserved as `<ckpt>.resharded-g1`).
    let cfg = write_job_epochs("chaos", "singd:diag", 2);
    let ckpt = std::env::temp_dir()
        .join(format!("singd-dist-proc-chaos-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let interrupted = digest_of_env(
        &cfg,
        &[
            "--ranks",
            "4",
            "--strategy",
            "factor-sharded",
            "--transport",
            "socket",
            "--elastic",
            "1",
            "--ckpt",
            &ckpt_s,
            "--ckpt-every",
            "2",
        ],
        &[("SINGD_CHAOS_ABORT", "2:3"), ("SINGD_SOCK_TIMEOUT_SECS", "20")],
    );
    let resharded = format!("{ckpt_s}.resharded-g1");
    assert!(
        std::path::Path::new(&resharded).exists(),
        "regroup must snapshot the recovery checkpoint as {resharded}"
    );
    let uninterrupted = digest_of(
        &cfg,
        &[
            "--ranks",
            "3",
            "--strategy",
            "factor-sharded",
            "--transport",
            "socket",
            "--resume",
            &resharded,
        ],
    );
    assert_eq!(
        interrupted, uninterrupted,
        "interrupted+resharded R=4→R'=3 run diverged from the uninterrupted \
         R'=3 run resumed from the same checkpoint"
    );
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&ckpt).ok();
    std::fs::remove_file(&resharded).ok();
    std::fs::remove_file(format!("{ckpt_s}.prev")).ok();
}
