//! Multi-process transport acceptance suite (ISSUE 3).
//!
//! Drives the actual `singd` binary (`CARGO_BIN_EXE_singd`) end to end:
//! `train --transport socket --ranks 4` makes the launched process rank 0
//! of a real 4-OS-process world (ranks 1–3 are re-exec'd workers joined
//! over a Unix-socket rendezvous). The run's `param_digest` — an FNV-1a
//! digest over every logged loss bit and every final parameter bit —
//! must be identical to `--transport local` and to serial `--ranks 1`,
//! for SINGD and KFAC, under both the replicated and factor-sharded
//! strategies. ci.sh runs this suite under a hard timeout so a hung
//! rendezvous fails fast instead of stalling CI.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_singd")
}

/// A tiny deterministic job: 4-batch MLP epoch over the synthetic
/// CIFAR stand-in (seconds per run, exercises the full dist stack).
fn write_job(name: &str, method: &str) -> PathBuf {
    let toml = format!(
        "label = \"dist-proc\"\n\
         [model]\narch = \"mlp\"\nwidth = 32\n\
         [data]\nclasses = 4\nn_train = 128\nn_test = 32\n\
         [optim]\nmethod = \"{method}\"\nlr = 0.01\ndamping = 0.1\nt_update = 1\n\
         [train]\nepochs = 1\nbatch_size = 32\nseed = 11\n"
    );
    let path = std::env::temp_dir()
        .join(format!("singd-dist-proc-{}-{name}.toml", std::process::id()));
    std::fs::write(&path, toml).expect("write job config");
    path
}

/// Run `singd train` with the given extra flags; return its param digest.
/// The parent env's SINGD_* knobs are cleared so the CI matrix cannot
/// leak a world size or transport into the child.
fn digest_of(config: &std::path::Path, extra: &[&str]) -> String {
    let mut cmd = Command::new(bin());
    cmd.arg("train").arg("--config").arg(config).args(extra);
    for k in [
        "SINGD_RANKS",
        "SINGD_TRANSPORT",
        "SINGD_ALGO",
        "SINGD_OVERLAP",
        "SINGD_RANK",
        "SINGD_WORLD",
        "SINGD_RENDEZVOUS",
    ] {
        cmd.env_remove(k);
    }
    let out = cmd.output().expect("spawn singd");
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(
        out.status.success(),
        "singd train {extra:?} failed ({}):\nstdout: {stdout}\nstderr: {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let tok = stdout
        .split_whitespace()
        .skip_while(|t| *t != "param_digest")
        .nth(1)
        .unwrap_or_else(|| panic!("no param_digest in output:\n{stdout}"))
        .to_string();
    assert_eq!(tok.len(), 16, "malformed digest '{tok}'");
    tok
}

#[test]
fn socket_ranks4_bitwise_matches_local_and_serial_for_singd_and_kfac() {
    for method in ["singd:diag", "kfac"] {
        let cfg = write_job(&method.replace(':', "-"), method);
        let serial = digest_of(&cfg, &["--ranks", "1"]);
        for strategy in ["replicated", "factor-sharded"] {
            // The default algo is ring; these two legs are the headline
            // "--algo ring on both transports" acceptance.
            let ring: &[&str] = &["--ranks", "4", "--strategy", strategy, "--algo", "ring"];
            let local = digest_of(&cfg, &[ring, &["--transport", "local"][..]].concat());
            let socket = digest_of(&cfg, &[ring, &["--transport", "socket"][..]].concat());
            assert_eq!(
                serial, local,
                "{method}/{strategy}: local ring ranks=4 diverged from serial"
            );
            assert_eq!(
                serial, socket,
                "{method}/{strategy}: socket ring ranks=4 (separate processes) diverged from serial"
            );
        }
        std::fs::remove_file(&cfg).ok();
    }
}

#[test]
fn star_and_ring_digests_match_across_transports() {
    // The algo axis end to end over real OS processes: star and ring
    // must produce identical param digests on both transports (one
    // method/strategy cell keeps the process count bounded; the full
    // shape grid lives in the in-process conformance suite).
    let cfg = write_job("algo-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for algo in ["star", "ring"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    algo,
                ],
            );
            assert_eq!(serial, digest, "{transport}/{algo}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn overlap_axis_digests_match_across_transports_and_processes() {
    // The overlap-invariance contract (ARCHITECTURE.md contract 4) over
    // real OS processes: --overlap 0 and --overlap 1 must produce
    // identical param digests on both transports — overlap reorders
    // *time*, never *reduction order*. One method under factor sharding
    // keeps the process count bounded; the full strategy × algo ×
    // overlap grid runs in-process in rust/tests/dist.rs.
    let cfg = write_job("overlap-axis", "singd:diag");
    let serial = digest_of(&cfg, &["--ranks", "1"]);
    for transport in ["local", "socket"] {
        for overlap in ["0", "1"] {
            let digest = digest_of(
                &cfg,
                &[
                    "--ranks",
                    "4",
                    "--strategy",
                    "factor-sharded",
                    "--transport",
                    transport,
                    "--algo",
                    "ring",
                    "--overlap",
                    overlap,
                ],
            );
            assert_eq!(serial, digest, "{transport}/overlap={overlap}: diverged from serial");
        }
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn socket_ranks2_smoke_with_csv_output() {
    // The multi-process smoke documented in README §Distributed: socket
    // transport also writes the rank-0 CSV, and workers stay silent.
    let cfg = write_job("smoke", "sgd");
    let out_csv = std::env::temp_dir()
        .join(format!("singd-dist-proc-smoke-{}.csv", std::process::id()));
    let mut cmd = Command::new(bin());
    cmd.arg("train")
        .arg("--config")
        .arg(&cfg)
        .args(["--ranks", "2", "--transport", "socket", "--out"])
        .arg(&out_csv);
    for k in [
        "SINGD_RANKS",
        "SINGD_TRANSPORT",
        "SINGD_ALGO",
        "SINGD_OVERLAP",
        "SINGD_RANK",
        "SINGD_WORLD",
        "SINGD_RENDEZVOUS",
    ] {
        cmd.env_remove(k);
    }
    let out = cmd.output().expect("spawn singd");
    assert!(
        out.status.success(),
        "socket smoke failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&out_csv).expect("rank 0 must write the CSV");
    assert!(csv.starts_with("label,step"), "csv header");
    assert!(csv.lines().count() >= 2, "csv rows");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&out_csv).ok();
}
