//! Integration tests across modules: config → experiment driver →
//! optimizer → model → metrics, plus runtime-vs-native cross checks.

use singd::config::JobConfig;
use singd::exp::{default_hyper, run_job};
use singd::model::{Model, Mlp};
use singd::numerics::Policy;
use singd::optim::Method;
use singd::proptest::Pcg;
use singd::structured::Structure;
use singd::train::{load_checkpoint, save_checkpoint, Schedule, TrainCfg};

const SMALL_JOB: &str = r#"
label = "it-job"
[model]
arch = "mlp"
width = 32
[data]
dataset = "cifar100"
classes = 5
n_train = 200
n_test = 60
[optim]
method = "singd:diag"
lr = 0.02
damping = 0.1
weight_decay = 0.01
precision = "bf16"
[train]
epochs = 3
batch_size = 40
seed = 3
"#;

#[test]
fn config_to_training_pipeline() {
    let cfg = JobConfig::from_str_toml(SMALL_JOB).unwrap();
    let res = run_job(&cfg);
    assert!(!res.diverged, "bf16 SINGD-Diag must be stable");
    assert_eq!(res.rows.len(), 3);
    assert!(res.final_test_err < 0.75, "must learn something: {}", res.final_test_err);
}

#[test]
fn every_method_trains_the_same_mlp() {
    for m in [
        "sgd",
        "adamw",
        "kfac",
        "ikfac",
        "ingd",
        "singd:diag",
        "singd:block:8",
        "singd:hier:8",
        "singd:rankk:1",
        "singd:toeplitz",
        "singd:tril",
    ] {
        let toml = SMALL_JOB.replace("singd:diag", m).replace("\"bf16\"", "\"fp32\"");
        let cfg = JobConfig::from_str_toml(&toml).unwrap();
        let res = run_job(&cfg);
        assert!(!res.diverged, "{m} diverged");
        assert!(res.final_test_err < 0.79, "{m} did not learn: {}", res.final_test_err);
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let mut rng = Pcg::new(5);
    let ds = singd::data::cifar100(&mut rng, 4, 120, 40);
    let mut mlp = Mlp::new(&mut rng, &[768, 16, 4]);
    let cfg = TrainCfg {
        method: Method::Sgd,
        epochs: 2,
        batch_size: 30,
        ..TrainCfg::default()
    };
    singd::train::train_image_model(&mut mlp, &ds, &cfg);
    let dir = std::env::temp_dir().join("singd_it_ckpt.bin");
    save_checkpoint(&dir, mlp.params()).unwrap();
    let loaded = load_checkpoint(&dir).unwrap();
    let mut mlp2 = Mlp::new(&mut Pcg::new(99), &[768, 16, 4]);
    *mlp2.params_mut() = loaded;
    let tb = ds.test_batch();
    let (l1, c1) = mlp.evaluate(&tb);
    let (l2, c2) = mlp2.evaluate(&tb);
    assert_eq!(c1, c2);
    assert!((l1 - l2).abs() < 1e-6);
    std::fs::remove_file(dir).ok();
}

#[test]
fn divergence_detection_stops_run() {
    // Absurd lr forces divergence; trainer must flag and stop early.
    let toml = SMALL_JOB
        .replace("lr = 0.02", "lr = 1000.0")
        .replace("method = \"singd:diag\"", "method = \"sgd\"")
        .replace("epochs = 3", "epochs = 10");
    let cfg = JobConfig::from_str_toml(&toml).unwrap();
    let res = run_job(&cfg);
    assert!(res.diverged);
    assert!(res.rows.len() < 30, "must stop early");
}

#[test]
fn bf16_policy_round_trips_params_through_trainer() {
    let cfg = JobConfig::from_str_toml(SMALL_JOB).unwrap();
    assert_eq!(cfg.hyper.policy, Policy::bf16_mixed());
    // All Singd variants keep parameters bf16-representable after training.
    let mut rng = Pcg::new(6);
    let ds = singd::data::cifar100(&mut rng, 4, 120, 40);
    let mut mlp = Mlp::new(&mut rng, &[768, 16, 4]);
    let tc = TrainCfg {
        method: Method::Singd { structure: Structure::Diagonal },
        hyper: cfg.hyper.clone(),
        schedule: Schedule::Constant,
        epochs: 2,
        batch_size: 30,
        ..TrainCfg::default()
    };
    singd::train::train_image_model(&mut mlp, &ds, &tc);
    for p in mlp.params() {
        for &v in p.data() {
            assert_eq!(v, singd::numerics::Dtype::Bf16.round(v));
        }
    }
}

#[test]
fn nan_gradient_injection_is_flagged() {
    // Failure injection: a NaN gradient must trip every optimizer's
    // divergence detector instead of silently poisoning the run.
    use singd::optim::{Hyper, KronStats};
    use singd::tensor::Mat;
    let mut rng = Pcg::new(8);
    for m in [
        Method::Sgd,
        Method::AdamW,
        Method::Kfac,
        Method::Singd { structure: Structure::Diagonal },
        Method::Singd { structure: Structure::Dense },
    ] {
        let mut opt = m.build(&[(4, 4)], &Hyper::default());
        let mut params = [rng.normal_mat(4, 4, 0.1)];
        let mut bad = Mat::zeros(4, 4);
        bad.set(1, 2, f32::NAN);
        let stats = KronStats { a: rng.normal_mat(8, 4, 1.0), g: rng.normal_mat(8, 4, 1.0) };
        opt.step(0, &mut params, std::slice::from_ref(&bad), std::slice::from_ref(&stats));
        assert!(opt.diverged(), "{} did not flag NaN gradient", m.name());
    }
}

#[test]
fn structured_factors_stay_in_class_over_long_runs() {
    // Train 100 steps with each structure and verify the K factor is still
    // exactly in its class (closure of the multiplicative update).
    use singd::optim::{Hyper, KronStats, Optimizer, Singd};
    let mut rng = Pcg::new(9);
    for s in [
        Structure::Diagonal,
        Structure::BlockDiag { k: 3 },
        Structure::RankKTril { k: 2 },
        Structure::Hierarchical { k1: 2, k2: 2 },
        Structure::TriuToeplitz,
        Structure::Tril,
    ] {
        let hp = Hyper { t_update: 1, ..Hyper::default() };
        let mut opt = Singd::new(&[(6, 9)], &hp, s);
        let mut params = [rng.normal_mat(6, 9, 0.1)];
        for t in 0..100 {
            let grads = [rng.normal_mat(6, 9, 0.1)];
            let stats = KronStats { a: rng.normal_mat(12, 9, 1.0), g: rng.normal_mat(12, 6, 1.0) };
            opt.step(t, &mut params, &grads, std::slice::from_ref(&stats));
        }
        let k = opt.k_factor(0);
        assert_eq!(k.structure().name(), s.name());
        // Pattern check: densify and verify zeros off-support by comparing
        // with the projection of itself.
        let dense = k.to_dense();
        let reproj = singd::structured::proj::proj(s, &dense.symmetrize());
        // Support containment: entries outside the class must be zero.
        let mask = reproj.to_dense();
        for r in 0..dense.rows() {
            for c in 0..dense.cols() {
                if s == Structure::TriuToeplitz || s == Structure::Tril {
                    continue; // weighted maps alter values; pattern via class-specific checks below
                }
                if mask.at(r, c) == 0.0 && r != c {
                    assert_eq!(dense.at(r, c), 0.0, "{}: off-support fill at ({r},{c})", s.name());
                }
            }
        }
        assert!(!k.has_nonfinite(), "{}", s.name());
    }
}

#[test]
fn sweep_integrates_with_driver() {
    let toml = SMALL_JOB.replace("epochs = 3", "epochs = 1");
    let base = JobConfig::from_str_toml(&toml).unwrap();
    let trials = singd::sweep::random_search(&base, &singd::sweep::Space::default(), 2, 9);
    assert_eq!(trials.len(), 2);
}

#[test]
fn grid_runner_covers_methods_and_precisions() {
    let toml = SMALL_JOB.replace("epochs = 3", "epochs = 1");
    let base = JobConfig::from_str_toml(&toml).unwrap();
    let methods = vec![
        (Method::AdamW, default_hyper(&Method::AdamW, false)),
        (
            Method::Ikfac { structure: Structure::Dense },
            default_hyper(&Method::Ikfac { structure: Structure::Dense }, false),
        ),
    ];
    let grid = singd::exp::run_grid(&base, &methods, &["fp32", "bf16"]);
    assert_eq!(grid.len(), 4);
}
